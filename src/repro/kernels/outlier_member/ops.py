"""jit wrapper: digest the key table, pad tiles, and dispatch.

``fused_hash_member`` is the op core/outliers dispatches to for the §6.2
sample predicate (η ∨ outlier membership + ``__outlier`` flag) and
``outlier_member`` is the membership-only probe behind
``member_keys``/``flag_outliers`` for multi-column keys.

Off-TPU the op compiles the sorted-digest binary search instead of running
the Pallas body in interpret mode: key digests are lexsorted once per call
(K log K, K = index capacity ≪ N) and every probe row then resolves in
log₂ K branchless descent steps — O(N log K) instead of the seed's O(N·K)
unrolled loop.  Tests force the Pallas path with ``use_pallas=True`` to
check the kernel itself.
"""

from __future__ import annotations

import functools
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.hashing import (
    DIGEST_SEED_HI,
    DIGEST_SEED_LO,
    hash_u01,
    key_digest,
    seed_mix,
)
from repro.kernels.outlier_member.kernel import (
    BLOCK_R,
    KEY_ROWS,
    LANE,
    outlier_member_tiles,
)
from repro.obs.kprof import profiled
from repro.relational.relation import SENTINEL_KEY, next_pow2

# CPU containers run the kernel body in interpret mode; on TPU set False.
INTERPRET = jax.default_backend() != "tpu"
USE_PALLAS = jax.default_backend() == "tpu"

# Largest key table the kernel keeps resident in VMEM ((BLOCK_R, Kp) f32
# match tile ≈ 2 MiB at the cap); larger indices take the XLA binary-search
# path, which is the better asymptotic shape there anyway.
MAX_KERNEL_KEYS = 2048


def _sorted_digests(key_cols: Sequence[jnp.ndarray]) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Lexsorted (hi, lo) digest lanes of the index key tuples."""
    hi, lo = key_digest(key_cols)
    order = jnp.lexsort((lo, hi))
    return hi[order], lo[order]


def _bsearch_member(phi, plo, shi, slo) -> jnp.ndarray:
    """probe digest ∈ sorted digests, branchless log₂ K descent.

    Finds the last index whose (hi, lo) pair is lexicographically ≤ the
    probe digest — the predicate is monotone along the sorted table, so a
    power-of-two descent needs no data-dependent control flow (jit-safe).
    """
    K = shi.shape[0]
    Kp = next_pow2(max(K, 2))
    if Kp != K:  # pad with the max digest: ≥ everything, never descended into
        shi = jnp.pad(shi, (0, Kp - K), constant_values=jnp.uint32(0xFFFFFFFF))
        slo = jnp.pad(slo, (0, Kp - K), constant_values=jnp.uint32(0xFFFFFFFF))
    pos = jnp.full(phi.shape, -1, jnp.int32)
    step = Kp  # step sizes Kp, Kp/2, …, 1 reach every index up to Kp−1
    while step >= 1:
        cand = pos + step
        safe_c = jnp.minimum(cand, Kp - 1)
        chi, clo = shi[safe_c], slo[safe_c]
        le = (cand < Kp) & ((chi < phi) | ((chi == phi) & (clo <= plo)))
        pos = jnp.where(le, cand, pos)
        step //= 2
    safe = jnp.clip(pos, 0, Kp - 1)
    return (pos >= 0) & (shi[safe] == phi) & (slo[safe] == plo)


@functools.partial(jax.jit, static_argnames=("m", "seed", "with_eta"))
def _fused_xla(cols, key_cols, m: float, seed: int, with_eta: bool):
    shi, slo = _sorted_digests(key_cols)
    phi, plo = key_digest(cols)
    member = _bsearch_member(phi, plo, shi, slo) & (cols[0] != SENTINEL_KEY)
    if not with_eta:
        return member, member
    keep = (hash_u01(cols, seed) < jnp.float32(m)) | member
    return keep, member


def _fused_pallas(cols, key_cols, m: float, seed: int,
                  interpret: Optional[bool] = None):
    R = cols[0].shape[0]
    C = len(cols)
    Rp = max(BLOCK_R, ((R + BLOCK_R - 1) // BLOCK_R) * BLOCK_R)
    panel = jnp.stack(
        [jnp.pad(jnp.asarray(c, jnp.int32), (0, Rp - R),
                 constant_values=jnp.int32(SENTINEL_KEY)) for c in cols],
        axis=1,
    )
    K = key_cols[0].shape[0]
    Kp = max(LANE, ((K + LANE - 1) // LANE) * LANE)
    kcols = tuple(
        jnp.pad(jnp.asarray(c, jnp.int32), (0, Kp - K),
                constant_values=jnp.int32(SENTINEL_KEY))
        for c in key_cols
    )
    khi, klo = key_digest(kcols)
    keys = jnp.zeros((KEY_ROWS, Kp), jnp.uint32).at[0].set(khi).at[1].set(klo)
    code = profiled(
        "outlier_member", outlier_member_tiles,
        panel, keys,
        seed_eta=seed_mix(seed),
        seed_hi=seed_mix(DIGEST_SEED_HI),
        seed_lo=seed_mix(DIGEST_SEED_LO),
        thresh=float(m),
        rows=R, padded=Rp,
        interpret=INTERPRET if interpret is None else interpret,
    )[:R, 0]
    return (code & 1) > 0, (code & 2) > 0


def fused_hash_member(
    cols: Sequence[jnp.ndarray],
    m: float,
    seed: int,
    key_cols: Sequence[jnp.ndarray],
    use_pallas: Optional[bool] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(η ∨ membership, membership) in one fused pass.

    cols: 1-D composite key columns of the probe rows (sentinel marks
    invalid); key_cols: index key columns, same arity, sentinel-masked.
    Returns two (R,) bool masks: keep = hash ≤ m ∨ member, and member (the
    ``__outlier`` flag source).  Membership of the padded / sentinel key
    slots can only fire on a 64-bit digest collision.
    """
    cols = tuple(jnp.asarray(c) for c in cols)
    key_cols = tuple(jnp.asarray(c) for c in key_cols)
    up = use_pallas if use_pallas is not None else USE_PALLAS
    if up and key_cols[0].shape[0] <= MAX_KERNEL_KEYS:
        return _fused_pallas(cols, key_cols, m, seed)
    R = cols[0].shape[0]
    return profiled("outlier_member", _fused_xla,
                    cols, key_cols, float(m), int(seed), True,
                    fallback=True, rows=R, padded=R)


def outlier_member(
    probe_cols: Sequence[jnp.ndarray],
    key_cols: Sequence[jnp.ndarray],
    use_pallas: Optional[bool] = None,
) -> jnp.ndarray:
    """Membership-only probe: probe tuple ∈ key tuples (digest identity)."""
    probe_cols = tuple(jnp.asarray(c) for c in probe_cols)
    key_cols = tuple(jnp.asarray(c) for c in key_cols)
    up = use_pallas if use_pallas is not None else USE_PALLAS
    if up and key_cols[0].shape[0] <= MAX_KERNEL_KEYS:
        return _fused_pallas(probe_cols, key_cols, 0.0, 0)[1]
    R = probe_cols[0].shape[0]
    return profiled("outlier_member", _fused_xla,
                    probe_cols, key_cols, 0.0, 0, False,
                    fallback=True, rows=R, padded=R)[1]
