"""Pure-jnp oracle for the fused η ∨ outlier-membership kernel.

Membership ``pk ∈ outlier_keys`` is answered on a 64-bit key digest carried
as two uint32 lanes (hi, lo) — two independently seeded splitmix32 folds of
the composite key columns (core/hashing.key_digest; jax x64 stays
disabled).  The oracle materializes the full (R, K) digest-pair equality
table, the dumbest correct formulation; kernel.py computes the same
decision tile by tile on the VPU and ops.py's XLA path replaces the dense
table with a sorted-digest binary search.

Rows whose FIRST key column is ``SENTINEL_KEY`` are never members (the
masked-probe convention of core/outliers.member_keys); index entries are
expected pre-masked the same way, so an invalid index slot (all-sentinel
tuple) can only match an invalid — already excluded — probe row.
"""

from __future__ import annotations

from typing import Sequence, Tuple

import jax.numpy as jnp

from repro.core.hashing import hash_threshold_mask_ref, key_digest
from repro.relational.relation import SENTINEL_KEY


def member_digest_ref(
    probe_cols: Sequence[jnp.ndarray],
    key_hi: jnp.ndarray,
    key_lo: jnp.ndarray,
) -> jnp.ndarray:
    """probe ∈ keys by dense (R, K) digest-pair comparison.

    probe_cols: 1-D int columns of the composite probe key (sentinel-masked
    for invalid rows); key_hi/key_lo: (K,) uint32 digest lanes of the index
    keys (core/hashing.key_digest of the sentinel-masked key columns).
    """
    phi, plo = key_digest(probe_cols)
    eq = (phi[:, None] == key_hi[None, :]) & (plo[:, None] == key_lo[None, :])
    return jnp.any(eq, axis=1) & (probe_cols[0] != SENTINEL_KEY)


def fused_hash_member_ref(
    cols: Sequence[jnp.ndarray],
    m: float,
    seed: int,
    key_hi: jnp.ndarray,
    key_lo: jnp.ndarray,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One logical pass: (η_{a,m} ∨ membership, membership) row masks.

    This is the §6.2 sample predicate ``hash(a) ≤ m OR a ∈ outlier_keys``
    with the ``__outlier`` flag decision, composed from the two existing
    oracles exactly the way the unfused path materializes them.
    """
    keep_eta = hash_threshold_mask_ref(cols, m, seed)
    member = member_digest_ref(cols, key_hi, key_lo)
    return keep_eta | member, member
