"""Pallas kernel: fused η hash-threshold + outlier-index membership (§6.2).

The skewed-workload sample predicate is ``hash(pk) ≤ m OR pk ∈
outlier_keys`` with pinned rows flagged ``__outlier`` (weight 1, Def. 5).
The seed implementation answered the membership half with a Python loop
unrolled over the whole index capacity — O(N·K) dispatches for multi-column
keys.  This kernel answers both halves in ONE pass over the key-column
tile:

  1. fold the composite key columns through the shared splitmix32 mixer
     (imported from core/hashing — bit-identical to hash_threshold) THREE
     ways at once: the η hash, and the (hi, lo) lanes of the 64-bit
     membership digest.  One ``mix(col)`` per column feeds all three folds
     — pure VPU elementwise work;
  2. η: u(h) < m, exactly the hash_threshold compare;
  3. membership: broadcast-compare the row digests against the (2, Kp)
     sorted-digest table resident in VMEM — the (BLOCK_R, Kp) equality tile
     never leaves VMEM (the TPU shape of the sorted-search idea: the table
     is scanned once per row tile instead of per key);
  4. emit an int32 code per row: bit0 = keep (η ∨ member), bit1 = member
     (the ``__outlier`` flag source).

Shapes: cols (R, C) int32 composite key panel (SENTINEL_KEY marks invalid
probe rows); keys (8, Kp) uint32 digest table (row 0 = hi, row 1 = lo,
rows 2.. padding); out (R, 1) int32.  Grid walks row tiles; the key table
is revisited every step (sequential TPU grid ⇒ safe).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.hashing import splitmix32
from repro.relational.relation import SENTINEL_KEY

BLOCK_R = 256
LANE = 128
KEY_ROWS = 8  # digest table sublane padding (uint32 tile multiple)


def _outlier_member_kernel(C, seed_eta, seed_hi, seed_lo, thresh,
                           col_ref, keys_ref, out_ref):
    """``seed_*``/``thresh`` are Python constants baked at trace time (the
    sampling ratio and seeds are plan-static in SVC)."""
    cols = col_ref[...]  # (BLOCK_R, C) int32
    shape = (cols.shape[0], 1)
    h_eta = jnp.full(shape, jnp.uint32(seed_eta), jnp.uint32)
    h_hi = jnp.full(shape, jnp.uint32(seed_hi), jnp.uint32)
    h_lo = jnp.full(shape, jnp.uint32(seed_lo), jnp.uint32)
    for c in range(C):
        mc = splitmix32(cols[:, c:c + 1].astype(jnp.uint32))
        h_eta = splitmix32(h_eta ^ mc)
        h_hi = splitmix32(h_hi ^ mc)
        h_lo = splitmix32(h_lo ^ mc)
    u = h_eta.astype(jnp.float32) * jnp.float32(1.0 / 4294967296.0)
    eta = u < jnp.float32(thresh)

    khi = keys_ref[0:1, :]  # (1, Kp)
    klo = keys_ref[1:2, :]
    match = (h_hi == khi) & (h_lo == klo)  # (BLOCK_R, Kp) broadcast compare
    member = jnp.sum(match.astype(jnp.float32), axis=1, keepdims=True) > 0.0
    member = member & (cols[:, 0:1] != jnp.int32(SENTINEL_KEY))
    keep = eta | member
    out_ref[...] = keep.astype(jnp.int32) + 2 * member.astype(jnp.int32)


@functools.partial(
    jax.jit, static_argnames=("seed_eta", "seed_hi", "seed_lo", "thresh", "interpret")
)
def outlier_member_tiles(
    cols: jnp.ndarray,
    keys: jnp.ndarray,
    seed_eta: int,
    seed_hi: int,
    seed_lo: int,
    thresh: float,
    interpret: bool = True,
) -> jnp.ndarray:
    """cols (R, C) int32 (R % BLOCK_R == 0), keys (8, Kp) uint32
    (Kp % 128 == 0, padded with sentinel-tuple digests); out (R, 1) int32
    codes (bit0 keep, bit1 member)."""
    R, C = cols.shape
    Kp = keys.shape[1]
    br = min(BLOCK_R, R)
    return pl.pallas_call(
        functools.partial(_outlier_member_kernel, C, seed_eta, seed_hi, seed_lo, thresh),
        out_shape=jax.ShapeDtypeStruct((R, 1), jnp.int32),
        grid=(max(1, R // BLOCK_R),),
        in_specs=[
            pl.BlockSpec((br, C), lambda r: (r, 0)),
            pl.BlockSpec((KEY_ROWS, Kp), lambda r: (0, 0)),
        ],
        out_specs=pl.BlockSpec((br, 1), lambda r: (r, 0)),
        interpret=interpret,
    )(cols, keys)
