"""Pallas kernel: one pass over the fleet panel moments every view at once.

The planner's per-epoch moment snapshot used to be a per-view Python loop
(one ``variance_comparison`` trace per view).  Here the whole fleet lives
in one stacked panel with views on the LANE axis and aligned rows on the
sublane axis: each (BLOCK_R, BLOCK_V) tile reduces a row slab of BLOCK_V
views with pure VPU elementwise math, and the five moment rows accumulate
into the (MOM_ROWS, BLOCK_V) output block across the row-tile grid steps
(sequential TPU grid ⇒ the revisited-block accumulation is safe, same
discipline as kernels/multi_agg).

Shapes: eight (Rp, Vp) f32 channel panels — x/valid/w/ompi per side,
TRANSPOSED from the host's (V, R) layout — with Rp a multiple of BLOCK_R
and Vp a multiple of BLOCK_V; out (MOM_ROWS, Vp) f32 with ref.py's moment
rows (rows N_MOMENTS.. are zero padding).  Padding rows/lanes carry
all-zero channels and therefore contribute zero to every reduction.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_R = 256  # aligned rows (sublanes) per grid step
BLOCK_V = 128  # views (lanes) per grid step
MOM_ROWS = 8   # N_MOMENTS padded to the f32 sublane multiple


def _fleet_moments_kernel(xn_ref, vn_ref, wn_ref, on_ref,
                          xo_ref, vo_ref, wo_ref, oo_ref, out_ref):
    rj = pl.program_id(1)

    @pl.when(rj == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    vn, wn, on = vn_ref[...], wn_ref[...], on_ref[...]
    t_new = wn * xn_ref[...] * vn
    t_old = wo_ref[...] * xo_ref[...] * vo_ref[...]
    d = t_new - t_old
    n_hat = jnp.sum(vn * wn, axis=0)
    s1 = jnp.sum(t_new, axis=0)
    s2 = jnp.sum(t_new * xn_ref[...], axis=0)
    ht_aqp = jnp.sum(on * t_new * t_new, axis=0)
    ht_corr = jnp.sum(jnp.minimum(on, oo_ref[...]) * d * d, axis=0)
    z = jnp.zeros_like(n_hat)
    out_ref[...] += jnp.stack([n_hat, s1, s2, ht_aqp, ht_corr, z, z, z])


@functools.partial(jax.jit, static_argnames=("interpret",))
def fleet_moments_tiles(xn, vn, wn, on, xo, vo, wo, oo,
                        interpret: bool = True) -> jnp.ndarray:
    """Eight (Rp, Vp) f32 panels, Rp % BLOCK_R == Vp % BLOCK_V == 0 →
    (MOM_ROWS, Vp) f32."""
    Rp, Vp = xn.shape
    tile = pl.BlockSpec((BLOCK_R, BLOCK_V), lambda vi, rj: (rj, vi))
    return pl.pallas_call(
        _fleet_moments_kernel,
        out_shape=jax.ShapeDtypeStruct((MOM_ROWS, Vp), jnp.float32),
        grid=(Vp // BLOCK_V, Rp // BLOCK_R),
        in_specs=[tile] * 8,
        out_specs=pl.BlockSpec((MOM_ROWS, BLOCK_V), lambda vi, rj: (0, vi)),
        interpret=interpret,
    )(xn, vn, wn, on, xo, vo, wo, oo)
