"""Pure-jnp oracle for the batched fleet moment pass.

One scan over the stacked fleet panel — every registered view's
correspondence-aligned clean/stale canonical-column pair, padded to a
common row count — emits, for ALL views at once, the sufficient
statistics the planner's moment snapshot needs:

  N_HAT    Σ v_new·w_new            estimated view rows (Σ 1/π)
  S1       Σ t_new                  weighted canonical-column total
  S2       Σ t_new·x_new            weighted canonical-column Σx²
  HT_AQP   Σ o_new·t_new²           §5.2.1 HT variance of SVC+AQP
  HT_CORR  Σ min(o_new,o_old)·d²    §5.2.2 HT variance of the correction

with t = w·x·valid per side and d = t_new − t_old over the outer-join row
space (absent rows carry t = 0, the Def. 4 Ø→0 fill).  These are exactly
the per-view numbers ``planner/costs.CostModel.snapshot`` derives from
``variance_comparison`` one view at a time — the batched pass replaces
that per-view Python loop with ONE compiled call (the retained loop is
the parity reference).  The §6.3 outlier stratum rides the channels: a
pinned row has w = 1 and ompi = 0 on its side, so it contributes fully to
the totals and nothing to either HT variance; padding rows have every
channel 0 and contribute nothing anywhere.

kernel.py computes the same reductions tile by tile with views on the
lane axis; this module is its parity oracle and the XLA-compiled CPU
path.
"""

from __future__ import annotations

import jax.numpy as jnp

# moment columns of the (V, N_MOMENTS) output
M_N = 0        # Σ 1/π over the clean sample (estimated rows)
M_S1 = 1       # Σ w·x (weighted canonical-column total)
M_S2 = 2       # Σ w·x² (weighted canonical-column sum of squares)
M_HT_AQP = 3   # Σ (1−π)·t² over the clean sample
M_HT_CORR = 4  # Σ min(1−π_new, 1−π_old)·d² over the joined row space
N_MOMENTS = 5


def fleet_moments_ref(
    x_new: jnp.ndarray,
    valid_new: jnp.ndarray,
    w_new: jnp.ndarray,
    ompi_new: jnp.ndarray,
    x_old: jnp.ndarray,
    valid_old: jnp.ndarray,
    w_old: jnp.ndarray,
    ompi_old: jnp.ndarray,
) -> jnp.ndarray:
    """Eight (V, R) f32 channel panels → (V, N_MOMENTS) f32, no view loop.

    Channels are row-aligned per view (the correspondence join's row
    space); rows beyond a view's joined length must be zero in EVERY
    channel.
    """
    xn = jnp.asarray(x_new, jnp.float32)
    vn = jnp.asarray(valid_new, jnp.float32)
    wn = jnp.asarray(w_new, jnp.float32)
    on = jnp.asarray(ompi_new, jnp.float32)
    xo = jnp.asarray(x_old, jnp.float32)
    vo = jnp.asarray(valid_old, jnp.float32)
    wo = jnp.asarray(w_old, jnp.float32)
    oo = jnp.asarray(ompi_old, jnp.float32)

    t_new = wn * xn * vn
    t_old = wo * xo * vo
    d = t_new - t_old
    n_hat = jnp.sum(vn * wn, axis=1)
    s1 = jnp.sum(t_new, axis=1)
    s2 = jnp.sum(t_new * xn, axis=1)
    ht_aqp = jnp.sum(on * t_new * t_new, axis=1)
    ht_corr = jnp.sum(jnp.minimum(on, oo) * d * d, axis=1)
    return jnp.stack([n_hat, s1, s2, ht_aqp, ht_corr], axis=1)
