"""jit wrapper: pad the fleet panel to tile multiples and dispatch.

``fleet_moments`` is the op the planner cost model calls once per epoch:
every view's §5.2.2 moment snapshot comes out of ONE compiled call over
the stacked (V, R) channel panels instead of a per-view
``variance_comparison`` trace.  A fixed fleet keeps one stable panel
shape, so every epoch after the first hits the jit cache.

Off-TPU the op compiles the reference math (the same single reduction
pass, lowered by XLA) instead of walking the Pallas grid in interpret
mode; tests force the Pallas path with ``use_pallas=True`` to check the
kernel itself.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.fleet_moments.kernel import (
    BLOCK_R,
    BLOCK_V,
    fleet_moments_tiles,
)
from repro.kernels.fleet_moments.ref import N_MOMENTS, fleet_moments_ref
from repro.obs.kprof import profiled

# CPU containers run the kernel body in interpret mode; on TPU set False.
INTERPRET = jax.default_backend() != "tpu"
USE_PALLAS = jax.default_backend() == "tpu"

_ref_jit = jax.jit(fleet_moments_ref)


def _pad_to(n: int, mult: int) -> int:
    return ((n + mult - 1) // mult) * mult


def fleet_moments(
    x_new, valid_new, w_new, ompi_new,
    x_old, valid_old, w_old, ompi_old,
    use_pallas: Optional[bool] = None,
) -> jnp.ndarray:
    """Eight (V, R) channel panels → (V, N_MOMENTS) per-view moments.

    Padding rows/views must carry all-zero channels (the fleet panel's
    contract) so they reduce to zero on every moment.
    """
    args = [jnp.asarray(a, jnp.float32) for a in (
        x_new, valid_new, w_new, ompi_new,
        x_old, valid_old, w_old, ompi_old,
    )]
    V, R = args[0].shape
    for a in args:
        if a.shape != (V, R):
            raise ValueError(f"ragged channel panel: {a.shape} != {(V, R)}")
    if V == 0:
        return jnp.zeros((0, N_MOMENTS), jnp.float32)
    if not (use_pallas if use_pallas is not None else USE_PALLAS):
        return profiled("fleet_moments", _ref_jit, *args,
                        fallback=True, rows=V, padded=V)
    Vp = _pad_to(max(V, BLOCK_V), BLOCK_V)
    Rp = _pad_to(max(R, BLOCK_R), BLOCK_R)
    padded = [jnp.pad(a, ((0, Vp - V), (0, Rp - R))).T for a in args]
    out = profiled("fleet_moments", fleet_moments_tiles, *padded,
                   rows=V, padded=Vp, interpret=INTERPRET)
    return out[:N_MOMENTS, :V].T
