"""Batched fleet moment pass: one scan snapshots every view's §5.2.2 stats.

The planner cost model stacks every registered view's correspondence-
aligned clean/stale canonical-column pair into one padded (V, R) panel
(repro.views.panel.FleetPanel) and computes all per-view moment
snapshots — estimated rows, weighted totals, and the AQP/CORR HT
variances behind ``variance_comparison`` — in a single compiled call.
Views live on the lane axis in the Pallas kernel; the XLA path compiles
the same one-pass reference reductions off-TPU.
"""

from repro.kernels.fleet_moments.ops import fleet_moments
from repro.kernels.fleet_moments.ref import (
    M_HT_AQP,
    M_HT_CORR,
    M_N,
    M_S1,
    M_S2,
    N_MOMENTS,
    fleet_moments_ref,
)

__all__ = [
    "M_HT_AQP",
    "M_HT_CORR",
    "M_N",
    "M_S1",
    "M_S2",
    "N_MOMENTS",
    "fleet_moments",
    "fleet_moments_ref",
]
