"""jit wrapper: mask/pad delta rows to tile multiples and dispatch.

``fused_clean_groupby`` is the op `core/maintenance.clean_sample` dispatches
to when the cleaning plan's delta sub-aggregation has the canonical SVC
shape (group-by-sum/count over η-filtered delta rows on a dense int key).
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.hashing import seed_mix as _seed_mix
from repro.kernels.fused_clean.kernel import BLOCK_G, BLOCK_R, fused_clean_tiles
from repro.obs.kprof import profiled

# CPU containers run the kernel body in interpret mode; on TPU set False.
INTERPRET = jax.default_backend() != "tpu"

# Pallas interpret mode walks the grid step by step and is slower than XLA
# on CPU, so off-TPU the fused op compiles the reference math instead — the
# same single pass (hash → mask → segmented accumulation, no sort, no
# materialized filtered relation), just lowered by XLA.  Tests force the
# Pallas path with ``use_pallas=True`` to check the kernel itself.
USE_PALLAS = jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("m", "seed", "num_groups"))
def _fused_ref_path(gid, vals, valid, pin_mask, m, seed, num_groups):
    from repro.kernels.fused_clean.ref import fused_clean_ref

    return fused_clean_ref(gid, vals, valid, m, seed, num_groups, pin_mask=pin_mask)


@functools.partial(jax.jit, static_argnames=("num_groups",))
def _fleet_path(gid, vals, valid, thresh, seed_mixes, num_groups):
    from repro.core.hashing import splitmix32

    V = gid.shape[0]
    h = splitmix32(seed_mixes[:, None] ^ splitmix32(gid.astype(jnp.uint32)))
    u = h.astype(jnp.float32) * jnp.float32(1.0 / 4294967296.0)
    keep = (u < thresh[:, None]) & valid
    g = jnp.where(keep, gid, num_groups)  # per-view overflow slot
    nseg = num_groups + 1
    gg = (g + nseg * jnp.arange(V, dtype=jnp.int32)[:, None]).reshape(-1)
    counts = jax.ops.segment_sum(
        keep.astype(jnp.float32).reshape(-1), gg, num_segments=V * nseg
    ).reshape(V, nseg)[:, :num_groups]
    sums = jax.ops.segment_sum(
        jnp.where(keep[:, :, None], vals, 0.0).reshape(V * gid.shape[1], -1),
        gg, num_segments=V * nseg,
    ).reshape(V, nseg, -1)[:, :num_groups, :]
    return counts, sums


def fused_clean_groupby_fleet(
    gid: jnp.ndarray,
    vals: jnp.ndarray,
    valid: jnp.ndarray,
    ms: Tuple[float, ...],
    seeds: Tuple[int, ...],
    num_groups: int,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One dispatch cleans a whole fleet's delta aggregations (pin-free).

    gid (V, R) int32 per-view group keys; vals (V, R, C) f32 value columns;
    valid (V, R) bool; ``ms``/``seeds`` the per-view sampling ratios and η
    seeds (the per-view seed folds exactly as in ``hash_threshold_ref``, so
    each view's slice is identical to its own ``fused_clean_groupby`` call).
    Returns (counts (V, G), sums (V, G, C)).  One batched segment pass —
    the offset-segment trick keeps V views in a single accumulator — lowers
    through XLA on every backend; the per-view Pallas kernel remains the
    single-view fast path.
    """
    thresh = jnp.asarray([float(m) for m in ms], jnp.float32)
    mixes = jnp.asarray([_seed_mix(int(s)) for s in seeds], jnp.uint32)
    V, R = gid.shape[0], gid.shape[1]
    return profiled(
        "fused_clean_fleet", _fleet_path,
        jnp.asarray(gid, jnp.int32), jnp.asarray(vals, jnp.float32),
        jnp.asarray(valid, bool), thresh, mixes, int(num_groups),
        rows=V * R, padded=V * R,
    )


def fused_clean_groupby(
    gid: jnp.ndarray,
    vals: jnp.ndarray,
    valid: jnp.ndarray,
    m: float,
    seed: int,
    num_groups: int,
    pin_mask: Optional[jnp.ndarray] = None,
    use_pallas: Optional[bool] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fused η_{gid,m} filter + per-group count/sum in one kernel pass.

    gid (R,) int32 group keys (must be < num_groups for rows that should
    land; others drop like segment_sum); vals (R, C) value columns; valid
    (R,) row mask; pin_mask (R,) optional outlier-pin membership (kept with
    weight 1 regardless of hash).  Returns (counts (G,), sums (G, C)).
    """
    squeeze = vals.ndim == 1
    if not (use_pallas if use_pallas is not None else USE_PALLAS):
        if squeeze:
            vals = vals[:, None]
        counts, sums = profiled(
            "fused_clean", _fused_ref_path,
            jnp.asarray(gid, jnp.int32), jnp.asarray(vals, jnp.float32),
            jnp.asarray(valid, bool),
            None if pin_mask is None else jnp.asarray(pin_mask, bool),
            float(m), int(seed), int(num_groups),
            fallback=True, rows=vals.shape[0], padded=vals.shape[0],
        )
        return counts, (sums[:, 0] if squeeze else sums)
    if squeeze:
        vals = vals[:, None]
    R, C = vals.shape
    Rp = ((R + BLOCK_R - 1) // BLOCK_R) * BLOCK_R
    Gp = ((num_groups + BLOCK_G - 1) // BLOCK_G) * BLOCK_G

    gid_m = jnp.where(jnp.asarray(valid, bool), jnp.asarray(gid, jnp.int32), -1)
    gid_p = jnp.pad(gid_m, (0, Rp - R), constant_values=-1)[:, None]
    if pin_mask is None:
        pin_p = jnp.zeros((Rp, 1), jnp.int8)
    else:
        pin_p = jnp.pad(jnp.asarray(pin_mask, jnp.int8), (0, Rp - R))[:, None]
    ones = jnp.ones((R, 1), jnp.float32)
    vals_ext = jnp.concatenate([ones, jnp.asarray(vals, jnp.float32)], axis=1)
    vals_p = jnp.pad(vals_ext, ((0, Rp - R), (0, 0)))

    out = profiled(
        "fused_clean", fused_clean_tiles,
        gid_p, pin_p, vals_p, seed_mix=_seed_mix(seed), thresh=float(m),
        num_groups=Gp, rows=R, padded=Rp, interpret=INTERPRET,
    )
    out = out[:num_groups]
    counts, sums = out[:, 0], out[:, 1:]
    return counts, (sums[:, 0] if squeeze else sums)
