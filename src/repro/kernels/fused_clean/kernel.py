"""Pallas kernel: fused η-filter + group-by sum/count over delta rows.

The SVC hot loop (§4.5) is "hash the delta row's view key, keep it if it
falls under the sample threshold, then fold it into its group's partial
aggregates".  The unfused pipeline runs that as two kernels with a full
materialized intermediate (hash_threshold mask → masked relation →
segment_aggsum); this kernel does both in ONE pass over the delta tile:

  1. splitmix32 the group-key column (bit-identical to hash_threshold) and
     compare against the threshold — VPU elementwise work;
  2. OR in the outlier-pin membership mask (Def. 5 rows enter the sample
     with weight 1 regardless of their hash);
  3. fold the keep-mask into the one-hot matrix and accumulate
     ``out[g, :] += onehotᵀ @ [1 | vals]`` on the MXU — column 0 of the
     output is the kept-row count, columns 1.. are the masked column sums.

No filtered intermediate ever exists: the keep decision lives only in the
one-hot tile in VMEM.  Grid and accumulation discipline follow
segment_aggsum: (group_tiles × row_tiles), the out block revisited across
row tiles (sequential TPU grid ⇒ safe accumulation).

Shapes: gid (R, 1) int32 (−1 ⇒ invalid/padded row, ≥ num_groups ⇒ dropped
like segment_sum's out-of-range rule); pin (R, 1) int8; vals (R, 1 + C)
f32 with a leading ones column; out (G, 1 + C) f32.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# The ONE splitmix32 mixer (core/hashing): importing it makes the
# bit-identical-hash invariant behind Prop. 2 structural — this kernel
# cannot drift from hash_threshold/the jnp oracle by copy-edit.
from repro.core.hashing import splitmix32

BLOCK_R = 256
BLOCK_G = 128


def _fused_clean_kernel(seed_mix, thresh, gid_ref, pin_ref, val_ref, out_ref):
    """``seed_mix``/``thresh`` are baked at trace time (plan-static in SVC)."""
    gi = pl.program_id(0)  # group tile
    ri = pl.program_id(1)  # row tile

    @pl.when(ri == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    gid = gid_ref[...]  # (BLOCK_R, 1) int32
    # η_{a,m}: the shared mixer + compare of kernels/hash_threshold
    h = splitmix32(jnp.uint32(seed_mix) ^ splitmix32(gid.astype(jnp.uint32)))
    u = h.astype(jnp.float32) * jnp.float32(1.0 / 4294967296.0)
    keep = (u < jnp.float32(thresh)) | (pin_ref[...] != 0)
    keep = keep & (gid >= 0)

    g0 = gi * BLOCK_G
    local = gid - g0  # group index within this tile
    cols = jax.lax.broadcasted_iota(jnp.int32, (gid.shape[0], BLOCK_G), 1)
    # the η decision folds into the one-hot: kept rows scatter, dropped
    # rows vanish — this is the "no materialized filtered intermediate"
    onehot = ((cols == local) & keep).astype(jnp.float32)  # (BLOCK_R, BLOCK_G)
    out_ref[...] += jax.lax.dot_general(
        onehot, val_ref[...], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


@functools.partial(jax.jit, static_argnames=("seed_mix", "thresh", "num_groups", "interpret"))
def fused_clean_tiles(
    gid: jnp.ndarray,
    pin: jnp.ndarray,
    vals: jnp.ndarray,
    seed_mix: int,
    thresh: float,
    num_groups: int,
    interpret: bool = True,
) -> jnp.ndarray:
    """gid (R,1) int32, pin (R,1) int8, vals (R, 1+C) f32 (R % BLOCK_R == 0);
    out (num_groups, 1+C) f32 with count in column 0.

    num_groups must be a multiple of BLOCK_G (ops.py pads).
    """
    R, C1 = vals.shape
    grid = (num_groups // BLOCK_G, max(1, R // BLOCK_R))
    br = min(BLOCK_R, R)
    return pl.pallas_call(
        functools.partial(_fused_clean_kernel, seed_mix, thresh),
        out_shape=jax.ShapeDtypeStruct((num_groups, C1), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, 1), lambda g, r: (r, 0)),
            pl.BlockSpec((br, 1), lambda g, r: (r, 0)),
            pl.BlockSpec((br, C1), lambda g, r: (r, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_G, C1), lambda g, r: (g, 0)),
        interpret=interpret,
    )(gid, pin, vals)
