"""Pure-jnp oracle for the fused η-filter + group aggregation kernel.

Composes the two existing oracles (hash_threshold_ref, segment_sum) exactly
the way the unfused plan executor does — materializing the keep mask — so
the fused kernel can be checked against the composition.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.hash_threshold.ref import hash_threshold_ref


def fused_clean_ref(
    gid: jnp.ndarray,
    vals: jnp.ndarray,
    valid: jnp.ndarray,
    m: float,
    seed: int,
    num_groups: int,
    pin_mask: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """gid (R,) int32; vals (R, C) f32; valid (R,) bool; pin_mask (R,) bool.

    Returns (counts (num_groups,) f32, sums (num_groups, C) f32) over the
    η_{gid,m} sample (∪ pinned rows), dropping invalid / out-of-range rows.
    """
    keep = hash_threshold_ref([jnp.asarray(gid, jnp.int32)], m, seed)
    if pin_mask is not None:
        keep = keep | jnp.asarray(pin_mask, bool)
    keep = keep & jnp.asarray(valid, bool)
    g = jnp.where(keep, jnp.asarray(gid, jnp.int32), num_groups)  # overflow slot
    nseg = num_groups + 1
    counts = jax.ops.segment_sum(keep.astype(jnp.float32), g, num_segments=nseg)[:num_groups]
    sums = jax.ops.segment_sum(
        jnp.where(keep[:, None], jnp.asarray(vals, jnp.float32), 0.0), g, num_segments=nseg
    )[:num_groups]
    return counts, sums
