from repro.kernels.fused_clean.ops import fused_clean_groupby
from repro.kernels.fused_clean.ref import fused_clean_ref

__all__ = ["fused_clean_groupby", "fused_clean_ref"]
