"""Pure-jnp oracle for the fleet-wide merge remainder.

After the fused delta aggregation (kernels/fused_clean), each scheduled
view still owes its *merge remainder*: outer-join the delta view onto the
stale sample on the group key and apply generalized projection — add the
insert-side aggregates, subtract the delete-side ones (Example 1 /
change-table IVM), keeping delta-only groups as new rows.  This op
computes that remainder for EVERY view of a fleet panel at once over the
padded ``(V, R)`` stale layout and dense ``(V, G)`` delta layouts.

Row space of the output: ``R + G`` rows per view — the first ``R`` are
the stale rows (keys preserved, aggregates upserted), the last ``G`` are
delta-only groups (key ``g`` where a delta group has no stale partner).
Float order is exactly the plan executor's generalized projection,
``(stale + ins) − del`` per aggregate in f32, so valid rows are
bit-equal to the per-view ``clean_sample`` path.

Validity semantics (mirrors relational/ops.outer_join_unique):

  * a stale row stays valid iff it was valid (its aggregates pick up the
    matching delta groups; invalid rows emit clean SENTINEL/0 padding);
  * a delta group emits its own row iff it is valid on either side and
    NO valid stale row carries its key (delete-cancellation: a group
    present only in the delete delta still emits ``0 − del``);
  * everything else is padding: key SENTINEL_KEY, values 0, valid False.

The oracle is the dumbest correct formulation (dense per-view gathers);
kernel.py computes the same upsert tile-by-tile with views on the lane
axis, and ops.py compiles this reference off-TPU.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.relational.relation import SENTINEL_KEY


def delta_only_rows(
    stale_keys: jnp.ndarray,   # (V, R) int32
    stale_valid: jnp.ndarray,  # (V, R) bool
    ins_valid: jnp.ndarray,    # (V, G) bool
    ins_vals: jnp.ndarray,     # (V, G, A) f32
    del_valid: jnp.ndarray,    # (V, G) bool
    del_vals: jnp.ndarray,     # (V, G, A) f32
):
    """Rows for delta groups with no valid stale partner.

    → (keys (V, G) i32, vals (V, G, A) f32, valid (V, G) bool).  Shared by
    the oracle and the Pallas dispatch path (ops.py): the upsert half
    differs per backend, this O(G) half does not.
    """
    stale_valid = stale_valid.astype(bool)
    ins_valid = ins_valid.astype(bool)
    del_valid = del_valid.astype(bool)
    V, _ = stale_keys.shape
    G = ins_valid.shape[1]

    k = stale_keys.astype(jnp.int32)
    in_range = stale_valid & (k >= 0) & (k < G)
    kc = jnp.clip(k, 0, max(G - 1, 0))
    present = jnp.zeros((V, G), jnp.float32)
    present = present.at[jnp.arange(V)[:, None], kc].add(
        in_range.astype(jnp.float32)
    )
    only = (ins_valid | del_valid) & ~(present > 0)
    only_vals = (
        jnp.where(ins_valid[..., None], ins_vals, 0.0)
        - jnp.where(del_valid[..., None], del_vals, 0.0)
    )
    only_vals = jnp.where(only[..., None], only_vals, 0.0)
    g_keys = jnp.broadcast_to(jnp.arange(G, dtype=jnp.int32)[None, :], (V, G))
    only_keys = jnp.where(only, g_keys, SENTINEL_KEY)
    return only_keys, only_vals, only


def fleet_merge_ref(
    stale_keys: jnp.ndarray,   # (V, R) int32 group keys (any value on invalid rows)
    stale_valid: jnp.ndarray,  # (V, R) bool
    stale_vals: jnp.ndarray,   # (V, R, A) f32 aggregate columns
    ins_valid: jnp.ndarray,    # (V, G) bool: insert-side delta group liveness
    ins_vals: jnp.ndarray,     # (V, G, A) f32 insert-side aggregates (dense key g)
    del_valid: jnp.ndarray,    # (V, G) bool: delete-side delta group liveness
    del_vals: jnp.ndarray,     # (V, G, A) f32 delete-side aggregates
):
    """→ (keys (V, R+G) i32, vals (V, R+G, A) f32, valid (V, R+G) bool)."""
    stale_valid = stale_valid.astype(bool)
    ins_valid = ins_valid.astype(bool)
    del_valid = del_valid.astype(bool)
    V, R = stale_keys.shape
    G = ins_valid.shape[1]

    k = stale_keys.astype(jnp.int32)
    in_range = stale_valid & (k >= 0) & (k < G)
    kc = jnp.clip(k, 0, max(G - 1, 0))

    # -- stale rows: upsert matching delta groups -----------------------------
    base = jnp.where(stale_valid[..., None], stale_vals, 0.0)
    ins_hit = jnp.take_along_axis(ins_valid, kc, axis=1) & in_range
    del_hit = jnp.take_along_axis(del_valid, kc, axis=1) & in_range
    ins_add = jnp.where(
        ins_hit[..., None], jnp.take_along_axis(ins_vals, kc[..., None], axis=1), 0.0
    )
    del_sub = jnp.where(
        del_hit[..., None], jnp.take_along_axis(del_vals, kc[..., None], axis=1), 0.0
    )
    # the executor's exact float order: (stale + ins) − del
    upd_vals = (base + ins_add) - del_sub
    upd_keys = jnp.where(stale_valid, k, SENTINEL_KEY)

    # -- delta-only rows: groups with no valid stale partner ------------------
    only_keys, only_vals, only = delta_only_rows(
        stale_keys, stale_valid, ins_valid, ins_vals, del_valid, del_vals
    )

    keys = jnp.concatenate([upd_keys, only_keys], axis=1)
    vals = jnp.concatenate([upd_vals, only_vals], axis=1)
    valid = jnp.concatenate([stale_valid, only], axis=1)
    vals = jnp.where(valid[..., None], vals, 0.0)
    return keys, vals, valid
