"""Fleet-wide merge remainder: one dispatch upserts every view's deltas.

See ref.py for semantics, kernel.py for the Pallas tiling, ops.py for
the public ``fleet_merge`` dispatch.
"""

from .kernel import BLOCK_G, BLOCK_R, BLOCK_V, fleet_merge_tiles
from .ops import INTERPRET, USE_PALLAS, fleet_merge
from .ref import delta_only_rows, fleet_merge_ref

__all__ = [
    "BLOCK_G",
    "BLOCK_R",
    "BLOCK_V",
    "INTERPRET",
    "USE_PALLAS",
    "delta_only_rows",
    "fleet_merge",
    "fleet_merge_ref",
    "fleet_merge_tiles",
]
