"""Pallas kernel: the fleet's stale-row upsert, views on the lane axis.

The merge remainder splits into two halves.  The *upsert* half — every
stale row picks up its matching insert/delete delta group and applies
``(stale + ins) − del`` — is the O(R·G) stage and lives here: the stale
key panel arrives TRANSPOSED as ``(Rp, Vp)`` with views on lanes (the
fleet_moments layout), the dense delta panels as ``(Gp, Vp)``, and each
grid step matches one ``(BLOCK_R, BLOCK_V)`` key tile against one
``BLOCK_G`` slab of groups.  A per-lane dynamic gather does not map to
the TPU's vector unit, so the gather is computed as dense one-hot
matching: for each group row ``g`` the tile-wide mask ``keys == g``
selects the (at most one) stale row per lane that upserts that group —
the same trick kernels/fused_clean uses for its scatter.

Float order is preserved exactly: the accumulator initializes to the
stale values at the first group slab, and the single matching group adds
its insert value THEN subtracts its delete value inside one loop
iteration (non-matching iterations contribute exact ``0.0``), so the
result is ``(stale + ins) − del`` bit-for-bit.

The other half — delta-only rows (groups with no stale partner) and the
final key sort — is cheap O(R + G) work and stays in XLA inside ops.py's
single dispatch for BOTH paths.

Padding contract: invalid stale rows carry key SENTINEL_KEY (never
matches a group id) and zero values; padded group rows carry zero
liveness.  Grid: (A, Vp/BLOCK_V, Rp/BLOCK_R, Gp/BLOCK_G) with the group
axis innermost — each output block is revisited only across the
sequential innermost dimension (safe accumulation).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_R = 256  # stale rows per tile
BLOCK_V = 128  # views (lanes) per tile
BLOCK_G = 128  # delta groups per slab


def _fleet_merge_kernel(skeys_ref, svals_ref, ivalid_ref, ivals_ref,
                        dvalid_ref, dvals_ref, out_ref):
    gk = pl.program_id(3)

    @pl.when(gk == 0)
    def _init():
        out_ref[...] = svals_ref[...]

    keys = skeys_ref[...]  # (BLOCK_R, BLOCK_V) int32
    g0 = gk * BLOCK_G

    def body(g, acc):
        gabs = g0 + g
        hit = (keys == gabs).astype(jnp.float32)      # (BLOCK_R, BLOCK_V)
        iv = ivalid_ref[pl.ds(g, 1), :]               # (1, BLOCK_V)
        dv = dvalid_ref[pl.ds(g, 1), :]
        ival = ivals_ref[0, pl.ds(g, 1), :]
        dval = dvals_ref[0, pl.ds(g, 1), :]
        # exact executor float order: (stale + ins) − del — the one
        # matching group applies both signs inside ONE iteration
        acc = acc + hit * (iv * ival)
        acc = acc - hit * (dv * dval)
        return acc

    out_ref[...] = jax.lax.fori_loop(0, BLOCK_G, body, out_ref[...])


@functools.partial(jax.jit, static_argnames=("interpret",))
def fleet_merge_tiles(
    skeys: jnp.ndarray,   # (Rp, Vp) int32, SENTINEL on invalid rows
    svals: jnp.ndarray,   # (A, Rp, Vp) f32, zero on invalid rows
    ivalid: jnp.ndarray,  # (Gp, Vp) f32 0/1
    ivals: jnp.ndarray,   # (A, Gp, Vp) f32
    dvalid: jnp.ndarray,  # (Gp, Vp) f32 0/1
    dvals: jnp.ndarray,   # (A, Gp, Vp) f32
    interpret: bool = True,
) -> jnp.ndarray:
    """→ (A, Rp, Vp) f32 upserted stale aggregate panels."""
    A, Rp, Vp = svals.shape
    Gp = ivalid.shape[0]
    grid = (A, Vp // BLOCK_V, Rp // BLOCK_R, Gp // BLOCK_G)
    return pl.pallas_call(
        _fleet_merge_kernel,
        out_shape=jax.ShapeDtypeStruct((A, Rp, Vp), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_R, BLOCK_V), lambda ai, vi, rj, gk: (rj, vi)),
            pl.BlockSpec((1, BLOCK_R, BLOCK_V), lambda ai, vi, rj, gk: (ai, rj, vi)),
            pl.BlockSpec((BLOCK_G, BLOCK_V), lambda ai, vi, rj, gk: (gk, vi)),
            pl.BlockSpec((1, BLOCK_G, BLOCK_V), lambda ai, vi, rj, gk: (ai, gk, vi)),
            pl.BlockSpec((BLOCK_G, BLOCK_V), lambda ai, vi, rj, gk: (gk, vi)),
            pl.BlockSpec((1, BLOCK_G, BLOCK_V), lambda ai, vi, rj, gk: (ai, gk, vi)),
        ],
        out_specs=pl.BlockSpec(
            (1, BLOCK_R, BLOCK_V), lambda ai, vi, rj, gk: (ai, rj, vi)
        ),
        interpret=interpret,
    )(skeys, svals, ivalid, ivals, dvalid, dvals)
