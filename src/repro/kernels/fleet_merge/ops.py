"""Public entry point for the fleet-wide merge remainder.

``fleet_merge(...)`` applies every view's merge remainder — upsert of
dense fused-groupby deltas into the padded stale-sample panel with
delete-cancellation — in one dispatch and returns the merged rows sorted
by group key (valid rows first, ascending; padding last), matching the
stable lexsort order ``relational.ops.compact`` gives the per-view path.

Backends (same convention as kernels/fleet_moments):

  * XLA (default off-TPU): jits the ref.py oracle plus the key sort.
  * Pallas (default on TPU, ``use_pallas=True`` elsewhere runs the
    interpreter): kernel.py computes the O(R·G) stale-row upsert with
    views on lanes; the O(R+G) delta-only rows and the sort are shared
    XLA glue inside the same jitted program.

Padding contract on outputs: invalid rows are key SENTINEL_KEY, values
0.0, valid False — callers may slice or re-pad without re-masking.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.obs.kprof import profiled
from repro.relational.relation import SENTINEL_KEY

from .kernel import BLOCK_G, BLOCK_R, BLOCK_V, fleet_merge_tiles
from .ref import delta_only_rows, fleet_merge_ref

# Pallas runs in interpret mode everywhere except real TPU backends.
INTERPRET = jax.default_backend() != "tpu"
USE_PALLAS = jax.default_backend() == "tpu"


def _pad_to(n: int, mult: int) -> int:
    return ((max(n, 1) + mult - 1) // mult) * mult


def _sort_by_key(keys, vals, valid):
    """Stable ascending sort on SENTINEL-masked keys per view.

    Valid keys are unique per view (group keys), so this reproduces the
    stable lexsort ordering of ``relational.ops.compact`` on valid rows
    and pushes all padding (SENTINEL_KEY) to the tail.
    """
    masked = jnp.where(valid, keys, SENTINEL_KEY)
    order = jnp.argsort(masked, axis=1, stable=True)
    keys = jnp.take_along_axis(masked, order, axis=1)
    vals = jnp.take_along_axis(vals, order[..., None], axis=1)
    valid = jnp.take_along_axis(valid, order, axis=1)
    return keys, vals, valid


@jax.jit
def _ref_sorted(stale_keys, stale_valid, stale_vals,
                ins_valid, ins_vals, del_valid, del_vals):
    out = fleet_merge_ref(
        stale_keys, stale_valid, stale_vals,
        ins_valid, ins_vals, del_valid, del_vals,
    )
    return _sort_by_key(*out)


@functools.partial(jax.jit, static_argnames=("v", "r", "g", "interpret"))
def _pallas_sorted(skeys_t, svals_t, ivalid_t, ivals_t, dvalid_t, dvals_t,
                   stale_keys, stale_valid,
                   ins_valid, ins_vals, del_valid, del_vals,
                   v: int, r: int, g: int, interpret: bool):
    # O(R·G) upsert on the padded transposed panels.
    upd = fleet_merge_tiles(
        skeys_t, svals_t, ivalid_t, ivals_t, dvalid_t, dvals_t,
        interpret=interpret,
    )
    upd_vals = jnp.transpose(upd, (2, 1, 0))[:v, :r]      # (V, R, A)
    upd_keys = jnp.where(stale_valid, stale_keys.astype(jnp.int32), SENTINEL_KEY)
    # O(R+G) tail shared with the oracle.
    only_keys, only_vals, only = delta_only_rows(
        stale_keys, stale_valid, ins_valid, ins_vals, del_valid, del_vals
    )
    keys = jnp.concatenate([upd_keys, only_keys], axis=1)
    vals = jnp.concatenate([upd_vals, only_vals], axis=1)
    valid = jnp.concatenate([stale_valid.astype(bool), only], axis=1)
    vals = jnp.where(valid[..., None], vals, 0.0)
    return _sort_by_key(keys, vals, valid)


def fleet_merge(
    stale_keys: jnp.ndarray,   # (V, R) int32 group keys
    stale_valid: jnp.ndarray,  # (V, R) bool
    stale_vals: jnp.ndarray,   # (V, R, A) f32 aggregate columns
    ins_valid: jnp.ndarray,    # (V, G) bool insert-delta group liveness
    ins_vals: jnp.ndarray,     # (V, G, A) f32 dense insert aggregates
    del_valid: jnp.ndarray | None = None,  # (V, G) bool delete-delta liveness
    del_vals: jnp.ndarray | None = None,   # (V, G, A) f32
    use_pallas: bool | None = None,
):
    """Batched merge remainder for a fleet panel.

    → ``(keys (V, R+G) i32, vals (V, R+G, A) f32, valid (V, R+G) bool)``
    sorted by key per view, padding last.  ``del_*=None`` means no
    delete side (views without ``with_deletes``).
    """
    if stale_keys.ndim != 2 or stale_vals.ndim != 3 or ins_vals.ndim != 3:
        raise ValueError("fleet_merge expects (V, R[, A]) / (V, G[, A]) panels")
    V, R = stale_keys.shape
    G = ins_valid.shape[1]
    A = stale_vals.shape[2]
    if stale_valid.shape != (V, R) or stale_vals.shape != (V, R, A):
        raise ValueError("ragged stale panel shapes")
    if ins_valid.shape != (V, G) or ins_vals.shape != (V, G, A):
        raise ValueError("ragged insert-delta panel shapes")
    if del_valid is None:
        del_valid = jnp.zeros((V, G), bool)
        del_vals = jnp.zeros((V, G, A), jnp.float32)
    if del_valid.shape != (V, G) or del_vals.shape != (V, G, A):
        raise ValueError("ragged delete-delta panel shapes")
    if V == 0 or G == 0 or A == 0:
        n = R + G
        return (
            jnp.full((V, n), SENTINEL_KEY, jnp.int32),
            jnp.zeros((V, n, A), jnp.float32),
            jnp.zeros((V, n), bool),
        )

    up = USE_PALLAS if use_pallas is None else use_pallas
    if not up:
        return profiled(
            "fleet_merge", _ref_sorted,
            stale_keys, stale_valid, stale_vals,
            ins_valid, ins_vals, del_valid, del_vals,
            fallback=True, rows=V * R, padded=V * R,
        )

    Vp = _pad_to(V, BLOCK_V)
    Rp = _pad_to(R, BLOCK_R)
    Gp = _pad_to(G, BLOCK_G)
    sv = stale_valid.astype(bool)
    skeys = jnp.where(sv, stale_keys.astype(jnp.int32), SENTINEL_KEY)
    skeys_t = jnp.pad(skeys, ((0, Vp - V), (0, Rp - R)),
                      constant_values=SENTINEL_KEY).T          # (Rp, Vp)
    svals = jnp.where(sv[..., None], stale_vals.astype(jnp.float32), 0.0)
    svals_t = jnp.transpose(
        jnp.pad(svals, ((0, Vp - V), (0, Rp - R), (0, 0))), (2, 1, 0)
    )                                                          # (A, Rp, Vp)
    iv = ins_valid.astype(jnp.float32)
    dv = del_valid.astype(jnp.float32)
    ivalid_t = jnp.pad(iv, ((0, Vp - V), (0, Gp - G))).T       # (Gp, Vp)
    dvalid_t = jnp.pad(dv, ((0, Vp - V), (0, Gp - G))).T
    ivals_t = jnp.transpose(
        jnp.pad(ins_vals.astype(jnp.float32), ((0, Vp - V), (0, Gp - G), (0, 0))),
        (2, 1, 0),
    )                                                          # (A, Gp, Vp)
    dvals_t = jnp.transpose(
        jnp.pad(del_vals.astype(jnp.float32), ((0, Vp - V), (0, Gp - G), (0, 0))),
        (2, 1, 0),
    )
    return profiled(
        "fleet_merge", _pallas_sorted,
        skeys_t, svals_t, ivalid_t, ivals_t, dvalid_t, dvals_t,
        stale_keys, sv, ins_valid, ins_vals, del_valid, del_vals,
        rows=V * R, padded=Vp * Rp,
        v=V, r=R, g=G, interpret=INTERPRET,
    )
