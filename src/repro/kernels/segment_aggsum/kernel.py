"""Pallas kernel: group-by partial aggregation as one-hot × values matmul.

The TPU-native adaptation of hash-partitioned group-by (DESIGN.md §2):
instead of scattering rows into buckets (pointer-chasing, serial on TPU),
each row-tile builds a one-hot matrix ``onehot[r, g] = (gid[r] == g)`` and
accumulates ``out[g, c] += onehotᵀ @ vals[r, c]`` on the MXU.  The group
axis is tiled to keep the one-hot block in VMEM; the grid walks
(row_tiles × group_tiles) with the output block revisited across row tiles
(sequential TPU grid ⇒ safe accumulation).

Shapes: gid (R,) int32; vals (R, C) f32; out (G, C) f32.  Grid:
(G // BLOCK_G, R // BLOCK_R); out block (BLOCK_G, C) indexed by g only.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_R = 256
BLOCK_G = 128


def _segsum_kernel(gid_ref, val_ref, out_ref):
    gi = pl.program_id(0)  # group tile
    ri = pl.program_id(1)  # row tile

    @pl.when(ri == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    gid = gid_ref[...]  # (BLOCK_R, 1) int32
    vals = val_ref[...]  # (BLOCK_R, C) f32
    g0 = gi * BLOCK_G
    local = gid - g0  # group index within this tile
    # one-hot on the MXU: (BLOCK_G, BLOCK_R) @ (BLOCK_R, C)
    cols = jax.lax.broadcasted_iota(jnp.int32, (gid.shape[0], BLOCK_G), 1)
    onehot = (cols == local).astype(jnp.float32)  # (BLOCK_R, BLOCK_G)
    out_ref[...] += jax.lax.dot_general(
        onehot, vals, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("num_groups", "interpret"))
def segment_sum_tiles(
    gid: jnp.ndarray, vals: jnp.ndarray, num_groups: int, interpret: bool = True
) -> jnp.ndarray:
    """gid (R,1) int32 (R % BLOCK_R == 0); vals (R, C); out (num_groups, C).

    num_groups must be a multiple of BLOCK_G (ops.py pads).
    """
    R, C = vals.shape
    grid = (num_groups // BLOCK_G, max(1, R // BLOCK_R))
    br = min(BLOCK_R, R)
    return pl.pallas_call(
        _segsum_kernel,
        out_shape=jax.ShapeDtypeStruct((num_groups, C), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, 1), lambda g, r: (r, 0)),
            pl.BlockSpec((br, C), lambda g, r: (r, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_G, C), lambda g, r: (g, 0)),
        interpret=interpret,
    )(gid, vals)
