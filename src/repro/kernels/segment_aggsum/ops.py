"""jit wrapper: pad rows/groups to tile multiples and dispatch."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.segment_aggsum.kernel import BLOCK_G, BLOCK_R, segment_sum_tiles
from repro.obs.kprof import profiled

INTERPRET = jax.default_backend() != "tpu"


def segment_sum(gid: jnp.ndarray, vals: jnp.ndarray, num_groups: int) -> jnp.ndarray:
    """Segment sum: out[g, c] = Σ_{i: gid[i]==g} vals[i, c].

    Out-of-range gids (e.g. the group-by overflow slot) are dropped, matching
    jax.ops.segment_sum semantics.
    """
    squeeze = vals.ndim == 1
    if squeeze:
        vals = vals[:, None]
    R, C = vals.shape
    Rp = ((R + BLOCK_R - 1) // BLOCK_R) * BLOCK_R
    Gp = ((num_groups + BLOCK_G - 1) // BLOCK_G) * BLOCK_G
    gid_p = jnp.pad(jnp.asarray(gid, jnp.int32), (0, Rp - R), constant_values=-1)[:, None]
    vals_p = jnp.pad(jnp.asarray(vals, jnp.float32), ((0, Rp - R), (0, 0)))
    out = profiled(
        "segment_aggsum", segment_sum_tiles, gid_p, vals_p,
        rows=R, padded=Rp, num_groups=Gp, interpret=INTERPRET,
    )
    out = out[:num_groups]
    return out[:, 0] if squeeze else out
