"""Pure-jnp oracle: jax.ops.segment_sum."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def segment_sum_ref(gid: jnp.ndarray, vals: jnp.ndarray, num_groups: int) -> jnp.ndarray:
    """gid (R,) int32; vals (R, C) f32; out (num_groups, C)."""
    return jax.ops.segment_sum(vals, gid, num_segments=num_groups)
