from repro.kernels.segment_aggsum import kernel, ops, ref

__all__ = ["kernel", "ops", "ref"]
