"""Hand-rolled AdamW with cosine schedule and global-norm clipping.

No optax in this container; the optimizer is a pair of pure functions over
parameter pytrees.  States (m, v) are fp32 and inherit the parameter
sharding specs (ZeRO-1 equivalent under FSDP param sharding).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def cosine_schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1)
    prog = jnp.clip(prog, 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def adamw_init(params: Any) -> Any:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def adamw_update(
    cfg: AdamWConfig, params: Any, grads: Any, opt_state: Any
) -> Tuple[Any, Any, dict]:
    step = opt_state["step"] + 1
    lr = cosine_schedule(cfg, step)

    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

    b1, b2 = cfg.b1, cfg.b2
    m = jax.tree.map(lambda mm, g: b1 * mm + (1 - b1) * g, opt_state["m"], grads)
    v = jax.tree.map(lambda vv, g: b2 * vv + (1 - b2) * g * g, opt_state["v"], grads)
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, mm, vv):
        mhat = mm / bc1
        vhat = vv / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

    new_params = jax.tree.map(upd, params, m, v)
    metrics = {"lr": lr, "grad_norm": gnorm, "clip_scale": scale}
    return new_params, {"m": m, "v": v, "step": step}, metrics
