"""Train step: CE loss, microbatch gradient accumulation, mixed precision.

The step is a pure function (state, batch) → (state, metrics) suitable for
jit with in/out shardings (launch/dryrun.py, launch/train.py).  Gradient
accumulation runs as a lax.scan over microbatches so the HLO stays O(1) in
the accumulation factor.  Per-domain loss sums are emitted as **SVC delta
feeds**: the training loop ingests them into the ViewManager's loss views
(the paper's technique operating on training telemetry).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.api import Model
from repro.models.parallel import ParallelCtx, constrain
from repro.training.optim import AdamWConfig, adamw_init, adamw_update
from jax.sharding import PartitionSpec as P


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class TrainState:
    params: Any
    opt_state: Any
    step: jnp.ndarray

    def tree_flatten(self):
        return (self.params, self.opt_state, self.step), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def init_train_state(model: Model, rng: jax.Array) -> TrainState:
    params = model.init(rng)
    return TrainState(params=params, opt_state=adamw_init(params), step=jnp.zeros((), jnp.int32))


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray, z_loss: float = 1e-4):
    """Token-mean CE with z-loss; accumulates in fp32 over a sharded vocab."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)  # (B,S)
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    loss = jnp.mean(nll) + z_loss * jnp.mean(lse**2)
    return loss, nll


def _split_micro(batch: Dict[str, jnp.ndarray], n: int) -> Dict[str, jnp.ndarray]:
    def sp(x):
        return x.reshape((n, x.shape[0] // n) + x.shape[1:])

    return {k: sp(v) for k, v in batch.items()}


def make_train_step(
    model: Model,
    opt_cfg: AdamWConfig,
    ctx: Optional[ParallelCtx] = None,
    microbatches: int = 1,
    moe_balance_coeff: float = 1e-2,
) -> Callable:
    cfg = model.cfg

    def loss_fn(params, mb) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
        logits, aux = model.forward(params, mb, ctx)
        if ctx is not None:
            logits = constrain(logits, ctx, P(ctx.dp_axes, None, ctx.tp_axis))
        loss, nll = cross_entropy(logits, mb["labels"])
        extras: Dict[str, jnp.ndarray] = {}
        if cfg.moe_experts and "moe_load" in aux and aux["moe_load"] is not None:
            load = aux["moe_load"]  # (L, E)
            frac = load / jnp.maximum(jnp.sum(load, -1, keepdims=True), 1.0)
            balance = jnp.mean(jnp.sum(frac * frac, -1)) * cfg.moe_experts
            loss = loss + moe_balance_coeff * balance
            extras["moe_load"] = jnp.sum(load, axis=0)  # (E,) delta feed for SVC
            extras["moe_balance"] = balance
        # per-domain loss sums (SVC delta feed): domain id in mb when present
        if "domain" in mb:
            dom = mb["domain"]  # (B,)
            per_seq = jnp.mean(nll, axis=-1)  # (B,)
            n_dom = 16
            onehot = jax.nn.one_hot(dom, n_dom, dtype=jnp.float32)
            extras["domain_loss_sum"] = onehot.T @ per_seq
            extras["domain_count"] = jnp.sum(onehot, axis=0)
        return loss, extras

    def train_step(state: TrainState, batch: Dict[str, jnp.ndarray]):
        if microbatches > 1:
            micro = _split_micro(batch, microbatches)

            def acc_body(carry, mb):
                gsum, lsum = carry
                (loss, extras), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                    state.params, mb
                )
                gsum = jax.tree.map(lambda a, g: a + g.astype(jnp.float32), gsum, grads)
                return (gsum, lsum + loss), extras

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            (gsum, lsum), extras = jax.lax.scan(acc_body, (zeros, 0.0), micro)
            grads = jax.tree.map(lambda g: g / microbatches, gsum)
            loss = lsum / microbatches
            extras = jax.tree.map(lambda x: jnp.sum(x, axis=0), extras)
        else:
            (loss, extras), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                state.params, batch
            )
        new_params, new_opt, opt_metrics = adamw_update(
            opt_cfg, state.params, grads, state.opt_state
        )
        metrics = {"loss": loss, **opt_metrics, **extras}
        return TrainState(new_params, new_opt, state.step + 1), metrics

    return train_step
