from repro.training.optim import AdamWConfig, adamw_init, adamw_update, cosine_schedule
from repro.training.train_step import TrainState, make_train_step, init_train_state

__all__ = [
    "AdamWConfig",
    "adamw_init",
    "adamw_update",
    "cosine_schedule",
    "TrainState",
    "make_train_step",
    "init_train_state",
]
