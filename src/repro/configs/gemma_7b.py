"""gemma-7b [dense] — arXiv:2403.08295 (hf tier).

28L d_model=3072 16H (kv=16) d_ff=24576 vocab=256000; GeGLU head_dim=256.
"""

import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma-7b", family="dense",
    n_layers=28, d_model=3072, n_heads=16, n_kv_heads=16, head_dim=256,
    d_ff=24576, vocab=256_000, act="geglu", rope_theta=10_000.0,
    remat="full",
    source="arXiv:2403.08295; hf",
)


def smoke() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, name="gemma-7b-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, head_dim=32, d_ff=128, vocab=512, compute_dtype="float32", remat="none",
    )
