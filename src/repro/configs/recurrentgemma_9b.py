"""recurrentgemma-9b [hybrid] — arXiv:2402.19427 (unverified tier).

38L d_model=4096 16H (MQA kv=1) d_ff=12288 vocab=256000; RG-LRU + local
attention in a (rec, rec, attn) pattern (1:2), window 2048.  Sub-quadratic:
eligible for long_500k (ring-buffer KV of width=window, O(1) rec state).
38 = 12 super-blocks × 3 + 2 trailing rec layers.
"""

import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-9b", family="hybrid",
    n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1, head_dim=256,
    d_ff=12288, vocab=256_000, act="geglu", rope_theta=10_000.0,
    attn_window=2048, block_pattern=("rec", "rec", "attn"),
    sub_quadratic=True,
    remat="full",
    source="arXiv:2402.19427; unverified",
)


def smoke() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, name="recurrentgemma-smoke", n_layers=5, d_model=64, n_heads=4,
        n_kv_heads=1, head_dim=16, d_ff=128, vocab=512, attn_window=16,
        compute_dtype="float32", remat="none",
    )
