"""granite-3-2b [dense] — hf:ibm-granite/granite-3.0-2b-base (hf tier).

40L d_model=2048 32H (GQA kv=8) d_ff=8192 vocab=49155.
"""

import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite-3-2b", family="dense",
    n_layers=40, d_model=2048, n_heads=32, n_kv_heads=8, head_dim=64,
    d_ff=8192, vocab=49155, act="swiglu", rope_theta=10_000.0,
    remat="full",
    source="hf:ibm-granite/granite-3.0-2b-base; hf",
)


def smoke() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, name="granite-3-2b-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, head_dim=16, d_ff=128, vocab=512, compute_dtype="float32", remat="none",
    )
