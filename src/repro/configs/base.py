"""Architecture + run configuration dataclasses.

One ``ArchConfig`` per assigned architecture lives in
``repro/configs/<id>.py`` (exact published numbers) together with a
``smoke()`` reduction of the same family for CPU tests.  Input-shape cells
(train_4k / prefill_32k / decode_32k / long_500k) are in ``shapes.py``.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | encdec
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    act: str = "swiglu"  # swiglu | geglu
    rope_theta: float = 10_000.0

    # multimodal (vlm / audio backbones; frontend is a stub per spec)
    m_rope: bool = False
    mrope_sections: Tuple[int, ...] = ()  # partitions of head_dim/2
    n_vision_tokens: int = 0  # stub patch embeddings prepended
    audio_frontend: bool = False  # stub frame embeddings into the encoder

    # MoE
    moe_experts: int = 0
    moe_top_k: int = 0
    moe_capacity_factor: float = 1.25

    # hybrid (RG-LRU + local attention)
    attn_window: int = 0
    block_pattern: Tuple[str, ...] = ()  # e.g. ("rec", "rec", "attn")
    rglru_conv_width: int = 4

    # xLSTM
    slstm_every: int = 0  # every k-th block is sLSTM (rest mLSTM)
    mlstm_heads: int = 0

    # encoder-decoder
    enc_layers: int = 0
    dec_layers: int = 0

    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    compute_dtype: str = "bfloat16"
    scan_layers: bool = True
    remat: str = "none"  # none | dots | full

    # notes for DESIGN.md §Arch-applicability
    sub_quadratic: bool = False  # supports long_500k decode
    source: str = ""

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    def param_count(self) -> int:
        """Analytic parameter count (for 6·N·D roofline)."""
        d, f, v = self.d_model, self.d_ff, self.vocab
        if self.family == "ssm":  # xLSTM
            per_m = d * (3 * d) + d * d  # q,k,v + out (inner = d)
            per_m += 2 * d * 2 * d  # up/gate projections (pf=2)
            per_s = 4 * d * d * 2  # W and R for 4 gates (hidden = d)
            n_s = self.n_layers // max(self.slstm_every, 1)
            n_m = self.n_layers - n_s
            return v * d + n_m * per_m + n_s * per_s
        attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        mlp = 3 * d * f
        if self.family == "moe":
            mlp = self.moe_experts * 3 * d * f + d * self.moe_experts
        if self.family == "hybrid":
            n_attn = sum(1 for b in self._pattern() if b == "attn")
            n_rec = self.n_layers - n_attn
            rec = d * (2 * d) + 2 * d + d * d  # in/gate proj + rglru + out
            return v * d + n_attn * (attn + mlp) + n_rec * (rec + mlp)
        if self.family == "encdec":
            enc = self.enc_layers * (attn + mlp)
            dec = self.dec_layers * (2 * attn + mlp)  # self + cross
            return v * d + enc + dec
        return v * d + self.n_layers * (attn + mlp)

    def active_param_count(self) -> int:
        """Active params per token (MoE: top-k experts only)."""
        if self.family != "moe":
            return self.param_count()
        d, f = self.d_model, self.d_ff
        attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        mlp_active = self.moe_top_k * 3 * d * f + d * self.moe_experts
        return self.vocab * d + self.n_layers * (attn + mlp_active)

    def _pattern(self) -> Tuple[str, ...]:
        if not self.block_pattern:
            return ()
        reps = (self.n_layers + len(self.block_pattern) - 1) // len(self.block_pattern)
        return (self.block_pattern * reps)[: self.n_layers]


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    """One (input-shape) cell of the dry-run matrix."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


TRAIN_4K = ShapeCell("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeCell("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeCell("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeCell("long_500k", 524288, 1, "decode")

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)


def shape_applicable(cfg: ArchConfig, cell: ShapeCell) -> Tuple[bool, str]:
    """Per-spec skip rules (recorded in the roofline table)."""
    if cell.name == "long_500k" and not cfg.sub_quadratic:
        return False, "full-attention arch: 512k dense decode is quadratic (spec skip)"
    return True, ""
