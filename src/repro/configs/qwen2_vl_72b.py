"""qwen2-vl-72b [vlm] — arXiv:2409.12191 (hf tier).

80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064; M-RoPE, dynamic
resolution.  The vision frontend is a STUB per spec: input_specs provides
precomputed patch embeddings occupying the first n_vision_tokens positions.
"""

import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-72b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=29568, vocab=152064, act="swiglu", rope_theta=1_000_000.0,
    m_rope=True, mrope_sections=(16, 24, 24), n_vision_tokens=256,
    remat="full",
    source="arXiv:2409.12191; hf",
)


def smoke() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, name="qwen2-vl-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, head_dim=16, d_ff=128, vocab=512,
        mrope_sections=(2, 3, 3), n_vision_tokens=4, compute_dtype="float32", remat="none",
    )
