"""seamless-m4t-large-v2 [audio] — arXiv:2308.11596 (hf tier).

24L (12 enc + 12 dec) d_model=1024 16H (kv=16) d_ff=8192 vocab=256206;
encoder-decoder.  The audio frontend is a STUB per spec: input_specs
provides precomputed frame embeddings (B, S_src, d_model) into the encoder.
"""

import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-large-v2", family="encdec",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16, head_dim=64,
    d_ff=8192, vocab=256206, act="swiglu", rope_theta=10_000.0,
    enc_layers=12, dec_layers=12, audio_frontend=True,
    remat="full",
    source="arXiv:2308.11596; hf",
)


def smoke() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, name="seamless-smoke", n_layers=4, d_model=64, n_heads=4,
        n_kv_heads=4, head_dim=16, d_ff=128, vocab=512,
        enc_layers=2, dec_layers=2, compute_dtype="float32", remat="none",
    )
