"""granite-moe-3b-a800m [moe] — hf:ibm-granite (hf tier).

32L d_model=1536 24H (GQA kv=8) d_ff=512 vocab=49155; MoE 40 experts top-8.
NOTE: the assignment lists both "MoE 40e top-8" and "32 experts top-8"; we
take 40 experts / top-8 from the shape field (see DESIGN.md).
"""

import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite-moe-3b-a800m", family="moe",
    n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8, head_dim=64,
    d_ff=512, vocab=49155, act="swiglu", rope_theta=10_000.0,
    moe_experts=40, moe_top_k=8,
    remat="full",
    source="hf:ibm-granite/granite-3.0-1b-a400m-base; hf",
)


def smoke() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, name="granite-moe-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, head_dim=16, d_ff=32, vocab=512,
        moe_experts=8, moe_top_k=2, compute_dtype="float32", remat="none",
    )
