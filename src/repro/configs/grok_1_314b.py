"""grok-1-314b [moe] — hf:xai-org/grok-1 (unverified tier).

64L d_model=6144 48H (GQA kv=8) d_ff=32768 vocab=131072; 8 experts top-2.
"""

import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="grok-1-314b", family="moe",
    n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=32768, vocab=131072, act="swiglu", rope_theta=10_000.0,
    moe_experts=8, moe_top_k=2,
    remat="full",
    source="hf:xai-org/grok-1; unverified",
)


def smoke() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, name="grok-1-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, head_dim=16, d_ff=128, vocab=512,
        moe_experts=4, moe_top_k=2, compute_dtype="float32", remat="none",
    )
