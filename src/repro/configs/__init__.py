"""Architecture registry: the 10 assigned archs + the paper's own workload.

``get_config(arch_id)`` / ``get_smoke_config(arch_id)`` accept the public
ids (e.g. "phi3-mini-3.8b") used by ``--arch`` on every launcher.
"""

from repro.configs.base import (
    ALL_SHAPES,
    DECODE_32K,
    LONG_500K,
    PREFILL_32K,
    TRAIN_4K,
    ArchConfig,
    ShapeCell,
    shape_applicable,
)
from repro.configs import (
    gemma_2b,
    gemma_7b,
    granite_3_2b,
    granite_moe_3b_a800m,
    grok_1_314b,
    phi3_mini_3_8b,
    qwen2_vl_72b,
    recurrentgemma_9b,
    seamless_m4t_large_v2,
    xlstm_1_3b,
)

_MODULES = {
    "phi3-mini-3.8b": phi3_mini_3_8b,
    "gemma-2b": gemma_2b,
    "gemma-7b": gemma_7b,
    "granite-3-2b": granite_3_2b,
    "qwen2-vl-72b": qwen2_vl_72b,
    "grok-1-314b": grok_1_314b,
    "granite-moe-3b-a800m": granite_moe_3b_a800m,
    "recurrentgemma-9b": recurrentgemma_9b,
    "xlstm-1.3b": xlstm_1_3b,
    "seamless-m4t-large-v2": seamless_m4t_large_v2,
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch_id: str) -> ArchConfig:
    return _MODULES[arch_id].CONFIG


def get_smoke_config(arch_id: str) -> ArchConfig:
    return _MODULES[arch_id].smoke()
