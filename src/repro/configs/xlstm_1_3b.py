"""xlstm-1.3b [ssm] — arXiv:2405.04517 (unverified tier).

48L d_model=2048 4H d_ff=0 vocab=50304; alternating sLSTM + mLSTM blocks
(1 sLSTM per 8 layers).  d_ff=0: feed-forward capacity lives inside the
blocks (up-projection factor 2).  Sub-quadratic: O(1) matrix-memory decode.
"""

import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4, head_dim=512,
    d_ff=0, vocab=50304, act="swiglu",
    slstm_every=8, mlstm_heads=4, sub_quadratic=True,
    remat="full",
    source="arXiv:2405.04517; unverified",
)


def smoke() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, name="xlstm-smoke", n_layers=4, d_model=64, n_heads=4,
        n_kv_heads=4, head_dim=16, slstm_every=2, mlstm_heads=4, vocab=512,
        compute_dtype="float32", remat="none",
    )
