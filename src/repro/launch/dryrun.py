import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST run before any other import (jax locks the device
count at first init).  For every cell we:

  1. build the production mesh (16×16 or 2×16×16),
  2. construct ShapeDtypeStruct inputs with NamedShardings (specs.py),
  3. jit(step).lower(...).compile(),
  4. record memory_analysis / cost_analysis and the trip-count-aware HLO
     analysis (FLOPs, bytes, collective traffic) for §Roofline,
  5. append the record to benchmarks/dryrun_results/<cell>.json.

Failures (sharding mismatch, OOM at compile, unsupported collective) are
recorded, not swallowed — they are bugs in the system.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma-2b --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all --mesh both
"""

import argparse
import json
import time
import traceback
from typing import Optional

import jax

from repro.configs import ALL_SHAPES, ARCH_IDS, get_config
from repro.configs.base import shape_applicable
from repro.launch import hlo_analysis
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import input_specs, make_ctx
from repro.models.api import get_model, param_counts
from repro.training.optim import AdamWConfig
from repro.training.train_step import make_train_step

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "benchmarks", "dryrun_results")


TRAIN_MICROBATCHES = 4  # gradient accumulation: bounds the live activation
                        # set (incl. the vocab-sharded logits block) per micro


def build_step(arch: str, cell, ctx, microbatches: int = TRAIN_MICROBATCHES):
    cfg = get_config(arch)
    model = get_model(cfg)
    if cell.kind == "train":
        step = make_train_step(model, AdamWConfig(), ctx=ctx, microbatches=microbatches)
        return step
    if cell.kind == "prefill":
        return lambda params, batch: model.prefill(
            params, batch, cache_len=cell.seq_len, ctx=ctx
        )
    if cell.kind == "decode":
        return lambda params, cache, tokens, pos: model.decode_step(
            params, cache, tokens, pos, ctx
        )
    raise ValueError(cell.kind)


def run_cell(arch: str, cell, multi_pod: bool, out_dir: str,
             skip_existing: bool = False) -> dict:
    mesh_name = "multi" if multi_pod else "single"
    cell_id = f"{arch}__{cell.name}__{mesh_name}"
    path = os.path.join(out_dir, cell_id + ".json")
    if skip_existing and os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    cfg = get_config(arch)
    rec = {
        "arch": arch, "shape": cell.name, "mesh": mesh_name,
        "kind": cell.kind, "seq_len": cell.seq_len,
        "global_batch": cell.global_batch,
        "chips": 512 if multi_pod else 256,
        "params": param_counts(cfg),
        "status": "pending",
    }
    ok, reason = shape_applicable(cfg, cell)
    if not ok:
        rec["status"] = "skipped"
        rec["skip_reason"] = reason
        _write(path, rec)
        return rec
    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        ctx = make_ctx(mesh, multi_pod)
        step = build_step(arch, cell, ctx)
        specs = input_specs(arch, cell, mesh, multi_pod)
        with mesh:
            if cell.kind == "train":
                lowered = jax.jit(step).lower(specs["state"], specs["batch"])
            elif cell.kind == "prefill":
                lowered = jax.jit(step).lower(specs["params"], specs["batch"])
            else:
                lowered = jax.jit(step).lower(
                    specs["params"], specs["cache"], specs["tokens"], specs["pos"]
                )
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

            rec["lower_s"] = round(t_lower, 2)
            rec["compile_s"] = round(t_compile, 2)
            rec["memory_analysis"] = _memory(compiled)
            rec["cost_analysis_raw"] = _cost(compiled)
            hlo = compiled.as_text()
            rec["hlo_analysis"] = _prune(hlo_analysis.analyze(hlo))
            rec["status"] = "ok"
    except Exception as e:  # noqa: BLE001 — record and continue
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    rec["total_s"] = round(time.time() - t0, 2)
    _write(path, rec)
    return rec


def _memory(compiled) -> Optional[dict]:
    try:
        ma = compiled.memory_analysis()
        if ma is None:
            return None
        out = {}
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "generated_code_size_in_bytes",
                     "alias_size_in_bytes"):
            if hasattr(ma, attr):
                out[attr] = int(getattr(ma, attr))
        return out or {"repr": str(ma)[:500]}
    except Exception as e:  # noqa: BLE001
        return {"error": str(e)[:200]}


def _cost(compiled) -> Optional[dict]:
    try:
        ca = compiled.cost_analysis()
        if not ca:
            return None
        return {k: float(v) for k, v in ca.items()
                if isinstance(v, (int, float)) and "{" not in k}
    except Exception as e:  # noqa: BLE001
        return {"error": str(e)[:200]}


def _prune(analysis: dict) -> dict:
    out = dict(analysis)
    out["loop_multipliers"] = {
        k: v for k, v in analysis.get("loop_multipliers", {}).items()
    } or {}
    # keep the record compact: top 12 loop multipliers by value
    lm = sorted(out["loop_multipliers"].items(), key=lambda kv: -kv[1])[:12]
    out["loop_multipliers"] = dict(lm)
    return out


def _write(path: str, rec: dict) -> None:
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all", help="arch id or 'all'")
    ap.add_argument("--shape", default="all", help="shape cell name or 'all'")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default=os.path.abspath(OUT_DIR))
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    archs = ARCH_IDS if args.arch == "all" else (args.arch,)
    shapes = ALL_SHAPES if args.shape == "all" else tuple(
        s for s in ALL_SHAPES if s.name == args.shape
    )
    meshes = {"single": (False,), "multi": (True,), "both": (False, True)}[args.mesh]

    n_ok = n_skip = n_err = 0
    for arch in archs:
        for cell in shapes:
            for mp in meshes:
                rec = run_cell(arch, cell, mp, args.out, args.skip_existing)
                tag = rec["status"]
                n_ok += tag == "ok"
                n_skip += tag == "skipped"
                n_err += tag == "error"
                msg = f"[{tag:7s}] {arch} × {cell.name} × {rec['mesh']}"
                if tag == "ok":
                    ha = rec["hlo_analysis"]
                    msg += (f"  flops={ha['flops']:.3e} coll={ha['collective_bytes']:.3e}B"
                            f" compile={rec['compile_s']}s")
                elif tag == "error":
                    msg += "  " + rec["error"][:120]
                print(msg, flush=True)
    print(f"\ndone: {n_ok} ok, {n_skip} skipped, {n_err} errors")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
