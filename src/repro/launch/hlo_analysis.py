"""Post-SPMD HLO analysis: trip-count-aware FLOPs, bytes, collective traffic.

``compiled.cost_analysis()`` counts each while-loop body ONCE — useless for
scan-over-layers programs (verified empirically; see tests).  We therefore
parse the optimized HLO text ourselves:

  * call graph + loop trip counts: lax.scan lowers to a while whose
    condition compares the induction variable against an integer constant;
    every computation's execution multiplier is propagated through
    while/call/fusion edges;
  * FLOPs: 2 × |result| × |contracting dims| per ``dot`` (operand shapes
    resolved through a per-computation symbol table) — elementwise FLOPs
    are ignored (sub-percent for these models);
  * memory bytes: Σ (result + operands) over top-level instructions that
    plausibly touch HBM (fusion/dot/copy/collectives/dynamic-slice...) —
    an approximation, but trip-count-correct and consistent across archs;
  * collective bytes: result-shape bytes per collective instruction —
    per-device wire traffic per step.

All values are per-device, per executed step.
"""

from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\(?[a-z0-9\[\],\s()]+\)?\{?[^=]*?)\s+([a-z][\w\-]*)\("
)
_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)
_SKIP_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
}
# ops whose RESULT plausibly materializes in HBM on TPU (elementwise chains
# are fused into these); layout-only ops (reshape/transpose/broadcast) and
# raw elementwise ops are excluded — a TPU fuses them into producers.
_HBM_OPS = {
    "fusion", "dot", "convolution", "copy", "dynamic-update-slice",
    "dynamic-slice", "slice", "reduce", "reduce-window", "scatter", "gather",
    "concatenate", "pad", "sort", "custom-call", "select-and-scatter",
}


def shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(text: str) -> List[int]:
    m = _SHAPE_RE.search(text)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


class Computation:
    def __init__(self, name: str):
        self.name = name
        self.lines: List[str] = []
        self.shapes: Dict[str, str] = {}  # instr name -> result type text


def _parse(hlo: str) -> Tuple[Dict[str, Computation], str]:
    comps: Dict[str, Computation] = {}
    cur = None
    entry = ""
    for line in hlo.splitlines():
        m = re.match(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*\{", line)
        if m and not line.startswith(" "):
            cur = Computation(m.group(2))
            comps[cur.name] = cur
            if m.group(1):
                entry = cur.name
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        s = line.strip()
        cur.lines.append(s)
        im = _INSTR_RE.match(s)
        if im:
            cur.shapes[im.group(1)] = im.group(2)
    return comps, entry


_CALL_RE = re.compile(r"(?:condition=|body=|to_apply=|calls=)%?([\w\.\-]+)")


def _trip_count(lines: List[str]) -> int:
    best = 1
    for ln in lines:
        for m in re.finditer(r"constant\((\d+)\)", ln):
            best = max(best, int(m.group(1)))
    return best


def _multipliers(comps: Dict[str, Computation], entry: str) -> Dict[str, float]:
    mult: Dict[str, float] = defaultdict(float)
    mult[entry] = 1.0
    order = [entry]
    visited = {entry}
    # BFS in call order; accumulate multiplicities (call graph is a DAG)
    i = 0
    while i < len(order):
        cur = order[i]
        i += 1
        for ln in comps[cur].lines:
            refs = _CALL_RE.findall(ln)
            if not refs:
                continue
            is_while = " while(" in ln or re.search(r"=\s*\S+\s+while\(", ln)
            trip = 1
            if is_while:
                cm = re.search(r"condition=%?([\w\.\-]+)", ln)
                if cm and cm.group(1) in comps:
                    trip = _trip_count(comps[cm.group(1)].lines)
            for r in set(refs):
                if r not in comps:
                    continue
                mult[r] += mult[cur] * (trip if is_while else 1)
                if r not in visited:
                    visited.add(r)
                    order.append(r)
    return mult


def _operand_names(ln: str) -> List[str]:
    m = re.search(r"\(([^)]*)\)", ln.split("=", 1)[1] if "=" in ln else ln)
    if not m:
        return []
    # older jax prints typed operands ("f32[8,256]{1,0} %copy.1"): take the
    # %-prefixed names, which survive the comma split inside shape brackets
    names = re.findall(r"%([\w\.\-]+)", m.group(1))
    if names:
        return names
    for tok in m.group(1).split(","):
        tok = tok.strip()
        nm = re.match(r"%?([\w\.\-]+)$", tok)
        if nm:
            names.append(nm.group(1))
    return names


def analyze(hlo: str) -> Dict[str, object]:
    comps, entry = _parse(hlo)
    mult = _multipliers(comps, entry)

    flops = 0.0
    mem_bytes = 0.0
    param_bytes = 0.0
    coll = {k: 0.0 for k in _COLLECTIVES}
    coll_count: Dict[str, float] = defaultdict(float)
    trip_info = {}

    for name, comp in comps.items():
        m = mult.get(name, 0.0)
        if m == 0.0:
            continue
        is_entry = name == entry
        for ln in comp.lines:
            im = _INSTR_RE.match(ln)
            if not im:
                continue
            _, result_type, op = im.groups()
            if op == "parameter" and is_entry:
                param_bytes += shape_bytes(result_type)  # weights/caches read
            if op in _SKIP_OPS:
                continue
            # ---- FLOPs from dots -------------------------------------------
            if op == "dot":
                dims = _shape_dims(result_type)
                csz = 1
                cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ln)
                ops = _operand_names(ln)
                if cm and ops:
                    lhs_type = comp.shapes.get(ops[0], "")
                    lhs_dims = _shape_dims(lhs_type)
                    for ci in cm.group(1).split(","):
                        if ci and int(ci) < len(lhs_dims):
                            csz *= lhs_dims[int(ci)]
                n = 1
                for d in dims:
                    n *= d
                flops += 2.0 * n * csz * m
            # ---- memory traffic --------------------------------------------
            # count WRITES of HBM-materializing results; ×2 below for the
            # matching reads (every result is read downstream ~once)
            rb = shape_bytes(result_type)
            if op in _HBM_OPS or op in _COLLECTIVES or op.rstrip("-start") in _COLLECTIVES:
                mem_bytes += rb * m
            # ---- collectives -----------------------------------------------
            for kind in _COLLECTIVES:
                if op == kind or op == kind + "-start":
                    coll[kind] += rb * m
                    coll_count[kind] += m
                    break
        if m > 1:
            trip_info[name] = m

    total_coll = sum(coll.values())
    mem_bytes = 2.0 * mem_bytes + param_bytes  # reads ≈ writes; params read once
    return {
        "flops": flops,
        "memory_bytes": mem_bytes,
        "collective_bytes": total_coll,
        "collectives": coll,
        "collective_counts": dict(coll_count),
        "loop_multipliers": trip_info,
    }


def collective_bytes(hlo: str) -> Dict[str, float]:
    """Back-compat wrapper: just the collective traffic."""
    a = analyze(hlo)
    out = dict(a["collectives"])
    out["total"] = a["collective_bytes"]
    out["instructions"] = a["collective_counts"]
    return out
