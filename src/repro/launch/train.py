"""End-to-end training driver.

Wires together: model zoo, token pipeline, AdamW train step, SVC-maintained
monitoring views (per-domain loss with CIs between full maintenance),
checkpoint/restart, straggler/failure monitoring with elastic re-planning.

CPU-runnable at smoke scale:

    PYTHONPATH=src python -m repro.launch.train --arch phi3-mini-3.8b \
        --smoke --steps 50 --batch 8 --seq 128 --ckpt /tmp/ckpt

Production scale uses the same code path with ``--mesh data,model`` under a
real TPU runtime (the dry-run proves the lowering).  ``--fail-at N``
simulates a host failure at step N: the monitor declares it, the elastic
planner shrinks the data axis, and training resumes from the last committed
checkpoint — the restart path exercised by tests/test_train_loop.py.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config, get_smoke_config
from repro.data.pipeline import PipelineConfig, PipelineStats, TokenPipeline
from repro.distributed.ft import FleetMonitor, plan_elastic_mesh
from repro.models import get_model
from repro.training import AdamWConfig, init_train_state, make_train_step


def build(args):
    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = get_model(cfg)
    pipe = TokenPipeline(
        PipelineConfig(vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch,
                       seed=args.seed)
    )
    stats = PipelineStats(m=args.svc_ratio, seed=args.seed)
    opt = AdamWConfig(lr=args.lr, total_steps=args.steps, warmup_steps=max(args.steps // 20, 5))
    step_fn = jax.jit(make_train_step(model, opt, microbatches=args.microbatches))
    return cfg, model, pipe, stats, step_fn


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi3-mini-3.8b")
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--svc-every", type=int, default=5, help="SVC refresh cadence")
    ap.add_argument("--svc-ratio", type=float, default=0.25)
    ap.add_argument("--mixture-every", type=int, default=25,
                    help="re-weight domains from SVC estimates")
    ap.add_argument("--fail-at", type=int, default=None,
                    help="simulate a host failure at this step")
    ap.add_argument("--hosts", type=int, default=4, help="simulated fleet size")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg, model, pipe, stats, step_fn = build(args)
    state = init_train_state(model, jax.random.PRNGKey(args.seed))
    start_step = 0

    ckpt = CheckpointManager(args.ckpt, keep=3, async_write=True) if args.ckpt else None
    if ckpt and ckpt.list_steps():
        state, extra = ckpt.restore(state)
        start_step = int(extra.get("step", 0))
        print(f"[restore] resumed from step {start_step}")

    fleet = FleetMonitor(n_hosts=args.hosts, timeout_s=30.0)
    losses = []
    t_begin = time.time()
    i = start_step
    while i < args.steps:
        batch = pipe.batch(i)
        t0 = time.perf_counter()
        state, metrics = step_fn(state, batch)
        loss = float(metrics["loss"])
        dt = time.perf_counter() - t0
        losses.append(loss)

        # fleet health (simulated heartbeats; per-host step times).  On the
        # injected failure step, time jumps past the heartbeat timeout: the
        # healthy hosts beat at the advanced clock, the failed host doesn't.
        now = time.time()
        jump = 31.0 if args.fail_at == i else 0.0
        for h in range(args.hosts):
            if args.fail_at is not None and i == args.fail_at and h == args.hosts - 1:
                continue  # host h stops heartbeating
            fleet.heartbeat(h, now + jump)
            fleet.report_step(h, dt)
        failed, stragglers = fleet.sweep(now + jump)
        if failed or stragglers:
            plan = plan_elastic_mesh(fleet.alive_hosts(), chips_per_host=4,
                                     model_parallel=1, target_data_parallel=args.hosts * 4)
            print(f"[elastic] lost hosts {failed + stragglers}; new plan: {plan}")
            if ckpt and ckpt.list_steps():
                state, extra = ckpt.restore(state)
                i = int(extra.get("step", i))
                print(f"[elastic] restored step {i}, continuing on shrunk fleet")

        # SVC monitoring: ingest per-domain loss deltas; refresh samples
        if "domain_loss_sum" in metrics:
            stats.ingest_step(np.asarray(metrics["domain_loss_sum"]),
                              np.asarray(metrics["domain_count"]))
        if i > 0 and i % args.svc_every == 0:
            stats.svc_refresh()
        if i > 0 and i % args.mixture_every == 0:
            w = stats.mixture_weights()
            pipe.set_mixture(w)
        if i > 0 and args.ckpt and i % args.ckpt_every == 0:
            stats.full_maintenance()  # IVM at checkpoint cadence (§7.6.2)
            ckpt.save(i, state, extra={"step": i})
        if i % args.log_every == 0:
            est, (lo, hi) = stats.loss_estimate(0)
            print(f"step {i:5d} loss {loss:.4f} ({dt*1e3:.0f} ms) "
                  f"dom0̂={est:.3f} [{lo:.3f},{hi:.3f}] alive={len(fleet.alive_hosts())}")
        i += 1

    if ckpt:
        ckpt.save(args.steps, state, extra={"step": args.steps})
        ckpt.wait()
    out = {
        "first_loss": losses[0] if losses else None,
        "last_loss": losses[-1] if losses else None,
        "steps": len(losses),
        "wall_s": time.time() - t_begin,
    }
    print(f"[done] {out}")
    return out


if __name__ == "__main__":
    main()
