"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never
touches jax device state (device count is locked at first jax init, and
smoke tests must see 1 CPU device while the dry-run sees 512 placeholders).
"""

from __future__ import annotations

import jax

from repro.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single-pod (256 chips) or 2×16×16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / CPU demos)."""
    return make_mesh((data, model), ("data", "model"))
