"""Serving driver: continuous-batching decode with SVC-monitored telemetry.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --smoke \
        --requests 16 --max-new 12
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.models import get_model
from repro.serving import Request, ServeEngine


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    engine = ServeEngine(model, params, max_batch=args.max_batch, max_seq=args.max_seq)

    rng = np.random.default_rng(args.seed)
    t0 = time.time()
    for rid in range(args.requests):
        plen = int(rng.integers(4, 16))
        prompt = rng.integers(0, cfg.vocab, plen).astype(np.int32)
        engine.submit(Request(rid=rid, prompt=prompt, max_new=args.max_new))
    done = engine.run()
    wall = time.time() - t0
    toks = sum(len(r.out_tokens) for r in done)
    lat = [r.t_done - r.t_submit for r in done if r.t_done]
    out = {
        "completed": len(done),
        "tokens": toks,
        "tok_per_s": toks / wall,
        "p50_latency_s": float(np.median(lat)) if lat else None,
        "ticks": engine.ticks,
    }
    print(f"[serve] {out}")
    return out


if __name__ == "__main__":
    main()
