"""ShapeDtypeStruct stand-ins for every model input (no allocation).

``input_specs(arch, shape, mesh, multi_pod)`` returns the exact pytrees the
dry-run lowers against: sharded SDS for the train state / params / caches /
batch, per (architecture × input-shape × mesh) cell.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.configs.base import ArchConfig, ShapeCell
from repro.distributed import sharding as shd
from repro.models.api import Model, get_model
from repro.models.parallel import ParallelCtx
from repro.training.train_step import TrainState, init_train_state

VISION_STUB_DIM = 1024


def make_ctx(mesh: Mesh, multi_pod: bool) -> ParallelCtx:
    return ParallelCtx(mesh=mesh, dp_axes=shd.dp_axes(multi_pod), tp_axis="model")


def _sds(shape, dtype, mesh, spec) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, dtype, sharding=NamedSharding(mesh, spec))


def batch_sds(cfg: ArchConfig, cell: ShapeCell, mesh: Mesh, multi_pod: bool,
              decode: bool = False) -> Dict[str, jax.ShapeDtypeStruct]:
    dp = shd.dp_axes(multi_pod)
    B = cell.global_batch
    S = 1 if decode else cell.seq_len
    out = {
        "tokens": _sds((B, S), jnp.int32, mesh, P(dp, None)),
        "labels": _sds((B, S), jnp.int32, mesh, P(dp, None)),
        "domain": _sds((B,), jnp.int32, mesh, P(dp)),
    }
    if cfg.family == "vlm" and not decode:
        out["vision_embeds"] = _sds(
            (B, cfg.n_vision_tokens, VISION_STUB_DIM), jnp.float32, mesh, P(dp, None, None)
        )
    if cfg.family == "encdec" and not decode:
        out["frames"] = _sds(
            (B, cell.seq_len, cfg.d_model), jnp.float32, mesh, P(dp, None, None)
        )
    return out


def state_sds(model: Model, mesh: Mesh, multi_pod: bool) -> Tuple[Any, Any]:
    """(TrainState SDS tree with shardings, spec tree) via eval_shape."""
    shapes = jax.eval_shape(lambda k: init_train_state(model, k), jax.random.PRNGKey(0))
    pspecs = shd.tree_param_specs(model.cfg, shapes.params, mesh)
    ospecs = {
        "m": pspecs["m"] if isinstance(pspecs, dict) and "m" in pspecs else pspecs,
        "v": pspecs,
        "step": P(),
    }
    ospecs = {"m": pspecs, "v": pspecs, "step": P()}
    specs = TrainState(params=pspecs, opt_state=ospecs, step=P())
    sds = jax.tree.map(
        lambda s, sp: _sds(s.shape, s.dtype, mesh, sp),
        (shapes.params, shapes.opt_state, shapes.step),
        (pspecs, ospecs, P()),
        is_leaf=lambda x: isinstance(x, P) or hasattr(x, "shape"),
    )
    state = TrainState(params=sds[0], opt_state=sds[1], step=sds[2])
    return state, specs


def params_sds(model: Model, mesh: Mesh, multi_pod: bool,
               serving: bool = False) -> Tuple[Any, Any]:
    """Param SDS.  ``serving=True``: bf16 weights, TP-resident (no FSDP)
    when they fit per-chip HBM — decode stops re-gathering weights per
    token (§Perf hillclimb D)."""
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    serve = serving and shd.serving_weights_fit(model.cfg, mesh)
    pspecs = shd.tree_param_specs(model.cfg, shapes, mesh, serving=serve)

    def leaf_sds(s, sp):
        dt = jnp.bfloat16 if (serve and s.dtype == jnp.float32 and s.ndim >= 2) else s.dtype
        return _sds(s.shape, dt, mesh, sp)

    sds = jax.tree.map(
        leaf_sds, shapes, pspecs,
        is_leaf=lambda x: hasattr(x, "shape") and not isinstance(x, P),
    )
    return sds, pspecs


def cache_sds(model: Model, cell: ShapeCell, mesh: Mesh, multi_pod: bool) -> Any:
    shapes = jax.eval_shape(
        lambda: model.init_cache(cell.global_batch, cell.seq_len)
    )
    cspecs = shd.cache_specs(model.cfg, shapes, mesh, multi_pod)
    return jax.tree.map(
        lambda s, sp: _sds(s.shape, s.dtype, mesh, sp), shapes, cspecs,
        is_leaf=lambda x: hasattr(x, "shape") and not isinstance(x, P),
    )


def input_specs(arch: str, cell: ShapeCell, mesh: Mesh, multi_pod: bool) -> Dict[str, Any]:
    """All SDS inputs for the cell's step function."""
    cfg = get_config(arch)
    model = get_model(cfg)
    if cell.kind == "train":
        state, _ = state_sds(model, mesh, multi_pod)
        return {"state": state, "batch": batch_sds(cfg, cell, mesh, multi_pod)}
    if cell.kind == "prefill":
        params, _ = params_sds(model, mesh, multi_pod)
        return {"params": params, "batch": batch_sds(cfg, cell, mesh, multi_pod)}
    if cell.kind == "decode":
        params, _ = params_sds(model, mesh, multi_pod, serving=True)
        dp = shd._maybe(mesh, shd.dp_axes(multi_pod), cell.global_batch)
        return {
            "params": params,
            "cache": cache_sds(model, cell, mesh, multi_pod),
            "tokens": _sds((cell.global_batch, 1), jnp.int32, mesh, P(dp, None)),
            "pos": _sds((), jnp.int32, mesh, P()),
        }
    raise ValueError(cell.kind)
