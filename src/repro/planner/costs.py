"""Online per-view cost and signal models for the budgeted control plane.

The planner prices every (view, action) pair each epoch; this module keeps
the inputs fresh at counter-read cost:

  * **Action costs** — EWMA estimates of ``svc_refresh`` and ``maintain``
    wall seconds per view, observed through the hooks ``ViewManager`` fires
    after every timed refresh/maintenance (the same ``maintenance_s``
    timers the benchmarks read) and seeded from the last recorded timer
    when one exists.  ``pin_costs`` freezes them to fixed values for
    deterministic tests and equal-price policy comparisons.
  * **Drift** — per-view pending delta rows, read from the counters
    ``ViewManager`` maintains per base (``drift_rows``): rows not yet in
    the clean sample (staleness bias of skipping) and rows not yet folded
    by IVM (the correction the next clean must carry).
  * **Traffic** — decayed query hit counts per view, observed through the
    ``query``/``query_batch`` hook.
  * **Moment snapshots** — σ²_S-style sufficient statistics of each view's
    canonical query (``variance_comparison`` HT variances plus the sample's
    value scale), recomputed lazily only when the view's samples actually
    moved (``ManagedView.sample_version``).

``features()`` stacks everything into the (V, N_FEATURES) panel the
compiled fleet scorer (kernels/fleet_score) consumes in one jitted call.
The moment columns come, by default, from ONE batched
``kernels/fleet_moments`` pass over the ViewManager's fleet panel
(``ViewManager.fleet_panel()``) — per-view laziness survives (only moved
views rebuild their panel slot) but the per-view ``variance_comparison``
trace is gone.  ``CostModel(use_panel=False)`` keeps the per-view
``snapshot()`` loop as the parity reference path.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core.estimators import _weights, variance_comparison
from repro.kernels.fleet_moments import (
    M_HT_AQP,
    M_HT_CORR,
    M_N,
    M_S1,
    M_S2,
)
from repro.views.panel import canonical_query
from repro.kernels.fleet_score import (
    F_AGE,
    F_COST_CLEAN,
    F_COST_MAINTAIN,
    F_COST_RETUNE,
    F_DRIFT_CLEAN,
    F_DRIFT_IVM,
    F_EX2,
    F_HT_AQP,
    F_HT_CORR,
    F_M,
    F_MEAN,
    F_N,
    F_TRAFFIC,
    N_FEATURES,
)

# default cost seeds (seconds) before the first observed timer
DEFAULT_REFRESH_S = 0.05
DEFAULT_MAINTAIN_S = 0.25
# a never-maintained view falls back to this clean-to-maintain cost ratio
MAINTAIN_OVER_REFRESH_SEED = 4.0
# a never-retuned view prices a retune-then-clean at this multiple of a
# plain clean (the retune re-derives BOTH samples from the materialized
# view before cleaning — strictly more work than the clean alone)
RETUNE_OVER_REFRESH_SEED = 2.0


# canonical_query moved to repro.views.panel (the fleet panel derives its
# slot columns from it); re-exported here for the public planner API.
__all__ = ["CostModel", "ViewCostStats", "canonical_query"]


@dataclasses.dataclass
class ViewCostStats:
    """Per-view EWMA costs, traffic, and the last moment snapshot."""

    refresh_s: float
    maintain_s: float
    retune_s: float
    traffic: float
    last_maintain_t: float
    snapshot_version: int = -1
    n_rows: float = 0.0
    ex2: float = 0.0
    mean: float = 0.0
    ht_aqp: float = 0.0
    ht_corr: float = 0.0


class CostModel:
    """Fleet-wide signal store; attach to a ViewManager to receive hooks."""

    def __init__(
        self,
        vm,
        clock: Callable[[], float] = time.monotonic,
        alpha: float = 0.3,
        default_refresh_s: float = DEFAULT_REFRESH_S,
        default_maintain_s: float = DEFAULT_MAINTAIN_S,
        use_panel: bool = True,
    ):
        self.vm = vm
        self._clock = clock
        self.alpha = float(alpha)
        self.default_refresh_s = float(default_refresh_s)
        self.default_maintain_s = float(default_maintain_s)
        self.frozen = False  # pin_costs: ignore observed wall times
        # False keeps the per-view variance_comparison snapshot loop (the
        # batched fleet panel's parity reference)
        self.use_panel = bool(use_panel)
        self.stats: Dict[str, ViewCostStats] = {}
        # views whose feature rows were non-finite on the LAST features()
        # pass (sanitized + quarantined, see _sanitize)
        self.last_poisoned: List[str] = []
        # mirror the observation stream into the unified metrics registry
        # (per-view label sets): the EWMAs stay the planner's pricing
        # inputs, the registry carries the raw observations for telemetry
        self._registry = getattr(vm, "metrics", None)

    def _observe_metric(self, name: str, view: str, value: float) -> None:
        if self._registry is not None:
            self._registry.histogram(name, view=view).observe(float(value))

    def attach(self) -> "CostModel":
        self.vm.cost_model = self
        return self

    def _stat(self, name: str) -> ViewCostStats:
        st = self.stats.get(name)
        if st is None:
            mv = self.vm.views[name]
            # seed from the per-op timers ViewManager already records: a
            # view whose last timed op was a maintain must NOT price its
            # cleans at the full-maintenance cost
            r_seed = float(mv.refresh_s) if mv.refresh_s > 0 else 0.0
            m_seed = float(mv.ivm_s) if mv.ivm_s > 0 else 0.0
            refresh = r_seed or self.default_refresh_s
            st = ViewCostStats(
                refresh_s=refresh,
                maintain_s=(m_seed
                            or r_seed * MAINTAIN_OVER_REFRESH_SEED
                            or self.default_maintain_s),
                retune_s=refresh * RETUNE_OVER_REFRESH_SEED,
                traffic=1.0,
                last_maintain_t=self._clock(),
            )
            self.stats[name] = st
        return st

    # -- observation hooks (fired by ViewManager) ----------------------------
    def _ewma(self, cur: float, obs: float) -> float:
        return (1.0 - self.alpha) * cur + self.alpha * obs

    def observe_refresh(self, name: str, dt: float) -> None:
        st = self._stat(name)
        if not self.frozen:
            st.refresh_s = self._ewma(st.refresh_s, float(dt))
        self._observe_metric("planner_refresh_s", name, dt)

    def observe_maintain(self, name: str, dt: float) -> None:
        st = self._stat(name)
        if not self.frozen:
            st.maintain_s = self._ewma(st.maintain_s, float(dt))
        st.last_maintain_t = self._clock()
        self._observe_metric("planner_maintain_s", name, dt)

    def observe_retune(self, name: str, dt: float) -> None:
        """A retune-then-clean's wall time prices FUTURE retunes, not plain
        cleans — folding it into refresh_s would inflate every clean score
        after each ratio step."""
        st = self._stat(name)
        if not self.frozen:
            st.retune_s = self._ewma(st.retune_s, float(dt))
        self._observe_metric("planner_retune_s", name, dt)

    def observe_traffic(self, name: str, n_queries: int) -> None:
        st = self._stat(name)
        st.traffic += float(n_queries)
        if self._registry is not None:
            self._registry.gauge("planner_traffic", view=name).set(st.traffic)

    def observe_ingest(self, base: str, n_rows: int) -> None:
        """Drift rides ViewManager's own counters; nothing to do here (the
        hook exists so subclasses can rate-model ingest streams)."""

    def decay_traffic(self, factor: float = 0.5) -> None:
        for name, st in self.stats.items():
            st.traffic *= factor
            if self._registry is not None:
                self._registry.gauge("planner_traffic",
                                     view=name).set(st.traffic)

    def pin_costs(self, refresh_s: float, maintain_s: float,
                  retune_s: Optional[float] = None) -> None:
        """Fix every view's action prices (deterministic tests, equal-price
        policy A/Bs); observed wall times stop moving the EWMAs.
        ``retune_s`` defaults to refresh × RETUNE_OVER_REFRESH_SEED."""
        self.default_refresh_s = float(refresh_s)
        self.default_maintain_s = float(maintain_s)
        rt = (float(retune_s) if retune_s is not None
              else float(refresh_s) * RETUNE_OVER_REFRESH_SEED)
        for name in self.vm.views:
            st = self._stat(name)
            st.refresh_s = float(refresh_s)
            st.maintain_s = float(maintain_s)
            st.retune_s = rt
        self.frozen = True

    # -- moment snapshots ----------------------------------------------------
    def snapshot(self, name: str, force: bool = False) -> ViewCostStats:
        """Refresh the §5.2.2 moment snapshot iff the samples moved."""
        mv = self.vm.views[name]
        st = self._stat(name)
        if not force and st.snapshot_version == mv.sample_version:
            return st
        q = canonical_query(mv)
        cmp = variance_comparison(mv.clean_sample, mv.stale_sample, q, mv.m)
        w = _weights(mv.clean_sample, mv.m)
        valid = mv.clean_sample.valid
        n_hat = float(jnp.sum(jnp.where(valid, w, 0.0)))
        if q.col is not None:
            x = jnp.asarray(mv.clean_sample.col(q.col), jnp.float32)
        else:
            x = jnp.ones(valid.shape, jnp.float32)
        s1 = float(jnp.sum(jnp.where(valid, w * x, 0.0)))
        s2 = float(jnp.sum(jnp.where(valid, w * x * x, 0.0)))
        st.n_rows = n_hat
        st.mean = s1 / max(n_hat, 1.0)
        st.ex2 = s2 / max(n_hat, 1.0)
        st.ht_aqp = float(cmp["var_aqp"])
        st.ht_corr = float(cmp["var_corr"])
        st.snapshot_version = mv.sample_version
        return st

    # -- the stacked feature panel ------------------------------------------
    def age_s(self, name: str) -> float:
        # clamped: clock skew (a rewound monotonic source in tests, an
        # injected skew fault) must not produce negative ages that flip
        # the starvation guard or the scorer's age term
        return max(0.0, self._clock() - self._stat(name).last_maintain_t)

    def features(self, names: Optional[Sequence[str]] = None,
                 use_pallas: Optional[bool] = None) -> np.ndarray:
        """(V, N_FEATURES) f32 panel for kernels/fleet_score, view order =
        ``names`` (default: ViewManager registration order).

        The moment columns come from ONE batched kernels/fleet_moments
        pass over the ViewManager's fleet panel (only views whose samples
        moved rebuild their slot); ``use_panel=False`` falls back to the
        per-view ``snapshot()`` loop, the retained parity reference."""
        names = list(names) if names is not None else list(self.vm.views)
        now = self._clock()
        out = np.zeros((len(names), N_FEATURES), np.float32)
        if self.use_panel and names:
            mom = self.vm.fleet_panel().moments(names, use_pallas=use_pallas)
            for i, name in enumerate(names):
                st = self._stat(name)
                mv = self.vm.views[name]
                n_hat = float(mom[i, M_N])
                st.n_rows = n_hat
                st.mean = float(mom[i, M_S1]) / max(n_hat, 1.0)
                st.ex2 = float(mom[i, M_S2]) / max(n_hat, 1.0)
                st.ht_aqp = float(mom[i, M_HT_AQP])
                st.ht_corr = float(mom[i, M_HT_CORR])
                st.snapshot_version = mv.sample_version
        else:
            for name in names:
                self.snapshot(name)
        for i, name in enumerate(names):
            st = self.stats[name]
            mv = self.vm.views[name]
            out[i, F_N] = st.n_rows
            out[i, F_EX2] = st.ex2
            out[i, F_MEAN] = st.mean
            out[i, F_HT_AQP] = st.ht_aqp
            out[i, F_HT_CORR] = st.ht_corr
            out[i, F_DRIFT_CLEAN] = self.vm.drift_rows(name, since="clean")
            out[i, F_DRIFT_IVM] = self.vm.drift_rows(name, since="ivm")
            out[i, F_TRAFFIC] = st.traffic
            out[i, F_COST_CLEAN] = st.refresh_s
            out[i, F_COST_MAINTAIN] = st.maintain_s
            out[i, F_COST_RETUNE] = st.retune_s
            out[i, F_AGE] = max(0.0, now - st.last_maintain_t)
            out[i, F_M] = mv.m
        fault_plan = getattr(self.vm, "fault_plan", None)
        if fault_plan is not None:
            out = fault_plan.poison_features(names, out)
        self.last_poisoned = self._sanitize(names, out)
        return out

    def _sanitize(self, names: Sequence[str], out: np.ndarray) -> List[str]:
        """A non-finite feature row (NaN-poisoned panel slot, corrupted
        moment) must not crash the whole scoring epoch OR feed garbage to
        the knapsack: the row is replaced with a neutral serve-stale row
        (zero drift/traffic/moments, EWMA costs kept) and the view is
        quarantined so the planner skips it until its backoff expires.  The
        cached moment snapshot is invalidated so the next un-poisoned epoch
        recomputes real moments instead of reusing the garbage."""
        bad = np.flatnonzero(~np.all(np.isfinite(out), axis=1))
        poisoned: List[str] = []
        for i in bad:
            name = names[i]
            st = self._stat(name)
            row = np.zeros(N_FEATURES, np.float32)
            row[F_COST_CLEAN] = st.refresh_s
            row[F_COST_MAINTAIN] = st.maintain_s
            row[F_COST_RETUNE] = st.retune_s
            row[F_M] = self.vm.views[name].m
            out[i] = row
            st.snapshot_version = -1
            for fld in ("n_rows", "ex2", "mean", "ht_aqp", "ht_corr"):
                if not np.isfinite(getattr(st, fld)):
                    setattr(st, fld, 0.0)
            poisoned.append(name)
            health = getattr(self.vm, "health", None)
            if health is not None:
                health.record_failure(
                    name, ValueError("non-finite planner features"))
        return poisoned
