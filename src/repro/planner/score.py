"""Fleet scoring: stack the cost-model features, score in ONE jitted call.

This is the planner's only device round trip per epoch: the per-view
feature gather (counter reads + lazily-refreshed moment snapshots) stacks
into a (V, N_FEATURES) panel and kernels/fleet_score prices every
(view, action) candidate simultaneously — no per-view Python loop touches
the scoring math, and a fixed fleet reuses one compiled shape forever.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.kernels.fleet_score import (
    A_CLEAN,
    A_MAINTAIN,
    A_RETUNE,
    A_SKIP,
    CORR_WINS,
    REC_M,
    fleet_scores,
)
from repro.planner.costs import CostModel


@dataclasses.dataclass
class FleetScores:
    """Host-side view of one scoring pass, in fleet order."""

    names: List[str]
    features: np.ndarray  # (V, N_FEATURES) f32, the scorer's exact input
    scores: np.ndarray    # (V, N_SCORES) f32

    def score(self, name: str, action: str) -> float:
        i = self.names.index(name)
        col = {"skip": A_SKIP, "clean": A_CLEAN, "maintain": A_MAINTAIN,
               "retune": A_RETUNE}[action]
        return float(self.scores[i, col])

    def corr_wins(self) -> Dict[str, bool]:
        """Per-view §5.2.2 estimator flip (CORR while ht_corr ≤ ht_aqp)."""
        return {n: bool(self.scores[i, CORR_WINS] > 0.5)
                for i, n in enumerate(self.names)}

    def recommended_m(self) -> Dict[str, float]:
        """Per-view sampling-ratio recommendation (REC_M): one clamped step
        up/down from the current ratio when the canonical total's relative
        standard error leaves the scorer's target band."""
        return {n: float(self.scores[i, REC_M])
                for i, n in enumerate(self.names)}


def score_fleet(
    cost_model: CostModel,
    names: Optional[Sequence[str]] = None,
    use_pallas: Optional[bool] = None,
) -> FleetScores:
    """Gather features and price the whole fleet in one compiled pass."""
    names = list(names) if names is not None else list(cost_model.vm.views)
    feats = cost_model.features(names, use_pallas=use_pallas)
    scores = np.asarray(fleet_scores(feats, use_pallas=use_pallas))
    return FleetScores(names=names, features=feats, scores=scores)
