"""Budgeted maintenance scheduler: greedy knapsack over (view, action).

``MaintenancePlanner.step()`` is the control-plane epoch: score the fleet
(one compiled kernels/fleet_score call), pick the best-scoring actions
whose predicted cost fits the per-epoch time budget, then execute them —
``svc_refresh_many`` for *clean* and *retune* (step the sampling ratio to
the scorer's REC_M, re-derive the sample pair, then clean), full
``maintain`` for *maintain* — feeding the observed wall times back into
the cost EWMAs.  Views the budget cannot reach serve stale this epoch,
exactly the per-view generalization of the paper's per-query
clean-vs-maintain economics (§5.2.2 / Fig 6).

With ``adapt_m``, a view whose recommendation differs from its current
ratio swaps its *clean* candidate for a *retune* candidate priced at the
retune EWMA (a retune re-derives both samples — strictly more work than
the clean it replaces, and PR 5 used to hide that cost inside the clean
price).  Recommendations are armed onto views only when their retune
action actually wins the knapsack, so a plain clean never silently pays
for a ratio step.

The **starvation guard** bounds how long "serve stale" can win: a view
whose full-maintenance age exceeds ``age_cap_s`` while it still carries
unapplied deltas is forced into the plan as a maintain, ahead of the
knapsack and regardless of remaining budget — staleness is bounded, never
silently unbounded (and the forced maintenance advances the pending-log
floor, so delta memory stays bounded too).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

from repro.kernels.fleet_score import A_CLEAN, A_MAINTAIN, A_RETUNE
from repro.obs import trace
from repro.planner.costs import CostModel
from repro.planner.score import FleetScores, score_fleet

COST_FIT_EPS = 1e-9  # float slack when charging predicted costs


def greedy_knapsack(cands, remaining: float,
                    chosen: Dict[str, "PlannedAction"]) -> float:
    """The planner's greedy fill: walk ``(score, view, action, cost)``
    candidates sorted by (-score, view, action) — the deterministic
    tie-break that keeps plans reproducible — charging each chosen action
    against ``remaining``.  Mutates ``chosen`` in place (one action per
    view; pre-seeded entries, e.g. the starvation guard's forced
    maintains, are respected) and returns the budget left.

    Shared verbatim by ``MaintenancePlanner.plan`` (one device) and
    ``distributed.fleet.ShardedFleet`` (the psum-closed global plan), so a
    sharded fleet fed the same candidate set makes bit-identical choices."""
    cands = sorted(cands, key=lambda c: (-c[0], c[1], c[2]))
    for score, name, action, cost in cands:
        if score <= 0.0 or name in chosen:
            continue
        if cost <= remaining + COST_FIT_EPS:
            chosen[name] = PlannedAction(
                view=name, action=action, score=score, predicted_s=cost
            )
            remaining -= cost
    return remaining


@dataclasses.dataclass
class PlannedAction:
    view: str
    action: str  # "clean" | "maintain" | "retune"
    score: float
    predicted_s: float
    forced: bool = False  # starvation guard, not knapsack
    actual_s: float = 0.0  # observed wall time once executed
    deadline_s: float = 0.0  # per-action timeout derived from the EWMA cost
    overrun: bool = False  # ran past its deadline → view degraded
    failed: bool = False  # raised during execution → view quarantined

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class PlanReport:
    """One epoch's decisions + accounting (the dashboard planner panel)."""

    epoch: int
    budget_s: float
    actions: List[PlannedAction]
    skipped: List[str]  # views left to serve stale this epoch
    corr_wins: Dict[str, bool]  # §5.2.2 estimator flip per view
    recommended_m: Dict[str, float] = dataclasses.field(default_factory=dict)
    # views excluded from the knapsack by the quarantine registry (serving
    # stale-with-wider-CI until their backoff expires or retries run out)
    quarantined: List[str] = dataclasses.field(default_factory=list)
    predicted_spend_s: float = 0.0
    actual_spend_s: float = 0.0
    # where the epoch's wall time went: the fleet snapshot + scoring pass,
    # the knapsack, and the executed actions (regression observability)
    snapshot_s: float = 0.0
    schedule_s: float = 0.0
    act_s: float = 0.0

    def to_dict(self) -> Dict:
        return {
            "epoch": self.epoch,
            "budget_s": self.budget_s,
            "predicted_spend_s": self.predicted_spend_s,
            "actual_spend_s": self.actual_spend_s,
            "snapshot_s": self.snapshot_s,
            "schedule_s": self.schedule_s,
            "act_s": self.act_s,
            "actions": [a.to_dict() for a in self.actions],
            "skipped": list(self.skipped),
            "corr_wins": dict(self.corr_wins),
            "recommended_m": dict(self.recommended_m),
            "quarantined": list(self.quarantined),
        }


class MaintenancePlanner:
    """Cost-model-driven clean/retune/maintain/serve-stale scheduler."""

    def __init__(
        self,
        vm,
        budget_s: float = 0.25,
        age_cap_s: float = 60.0,
        clock: Callable[[], float] = time.monotonic,
        cost_model: Optional[CostModel] = None,
        traffic_decay: float = 0.5,
        use_pallas: Optional[bool] = None,
        adapt_m: bool = False,
        deadline_factor: float = 10.0,
        deadline_floor_s: float = 0.5,
        max_retries: Optional[int] = None,
        backoff_base: Optional[int] = None,
        backoff_cap: Optional[int] = None,
    ):
        self.vm = vm
        self.budget_s = float(budget_s)
        self.age_cap_s = float(age_cap_s)
        self.traffic_decay = float(traffic_decay)
        self.use_pallas = use_pallas
        # failure axis: an action running past deadline_factor × its EWMA
        # prediction (never below the floor — cold EWMAs and compile spikes
        # would otherwise quarantine healthy views) counts as a failure
        self.deadline_factor = float(deadline_factor)
        self.deadline_floor_s = float(deadline_floor_s)
        vm.health.configure(max_retries=max_retries,
                            backoff_base=backoff_base,
                            backoff_cap=backoff_cap)
        self.cost_model = (cost_model or CostModel(vm, clock=clock)).attach()
        # opt-in m adaptation: plan() writes the scorer's REC_M onto each
        # ManagedView and svc_refresh applies it (ViewManager.adaptive_m)
        self.adapt_m = bool(adapt_m)
        if self.adapt_m:
            vm.adaptive_m = True
        self.epoch = 0
        self.last_report: Optional[PlanReport] = None

    # -- decision ------------------------------------------------------------
    def plan(self, budget_s: Optional[float] = None) -> PlanReport:
        """Score the fleet and pick this epoch's actions (no execution)."""
        budget = self.budget_s if budget_s is None else float(budget_s)
        clock = self.vm.clock
        t0 = clock()
        with trace.span("snapshot", epoch=self.epoch):
            fs: FleetScores = score_fleet(
                self.cost_model, use_pallas=self.use_pallas
            )
        snapshot_s = clock() - t0
        t0 = clock()
        sched_sp = trace.span("schedule", epoch=self.epoch)
        sched_sp.__enter__()
        rec_m = fs.recommended_m()
        chosen: Dict[str, PlannedAction] = {}
        remaining = budget
        # quarantined views sit the epoch out (serve stale with widened CI)
        # until their exponential backoff expires; then they re-enter the
        # candidate set like any other view.  Feature sanitization may have
        # just quarantined NaN-poisoned views inside score_fleet, so this
        # check runs AFTER the scoring pass.
        health = self.vm.health
        blocked = {n for n in fs.names if health.blocked(n)}

        # starvation guard: overdue drifting views maintain unconditionally
        for name in fs.names:
            if name in blocked:
                continue
            if (self.cost_model.age_s(name) > self.age_cap_s
                    and self.vm.drift_rows(name, since="ivm") > 0):
                cost = self.cost_model._stat(name).maintain_s
                chosen[name] = PlannedAction(
                    view=name, action="maintain", forced=True,
                    score=float(fs.scores[fs.names.index(name), A_MAINTAIN]),
                    predicted_s=cost,
                )
                remaining -= cost

        # greedy knapsack over the remaining (view, action) candidates;
        # deterministic tie-break by (view, action) keeps plans reproducible
        cands = []
        for i, name in enumerate(fs.names):
            if name in chosen or name in blocked:
                continue
            st = self.cost_model._stat(name)
            rm = rec_m.get(name, 0.0)
            if self.adapt_m and rm > 0.0 and rm != self.vm.views[name].m:
                # the scorer wants a ratio step: the view's clean slot
                # BECOMES a retune (ratio step + sample re-derivation +
                # clean), priced at the retune EWMA — rec_m is an exact
                # clamped step from m, so float inequality is a safe gate
                cands.append((float(fs.scores[i, A_RETUNE]), name, "retune",
                              st.retune_s))
            else:
                cands.append((float(fs.scores[i, A_CLEAN]), name, "clean",
                              st.refresh_s))
            cands.append((float(fs.scores[i, A_MAINTAIN]), name, "maintain", st.maintain_s))
        remaining = greedy_knapsack(cands, remaining, chosen)

        actions = [chosen[n] for n in fs.names if n in chosen]
        for act in actions:
            act.deadline_s = max(self.deadline_floor_s,
                                 self.deadline_factor * act.predicted_s)
        schedule_s = clock() - t0
        sched_sp.set(chosen=len(actions),
                     skipped=len(fs.names) - len(actions))
        sched_sp.__exit__(None, None, None)
        return PlanReport(
            epoch=self.epoch,
            budget_s=budget,
            actions=actions,
            skipped=[n for n in fs.names if n not in chosen],
            corr_wins=fs.corr_wins(),
            recommended_m=rec_m,
            quarantined=sorted(blocked),
            predicted_spend_s=sum(a.predicted_s for a in actions),
            snapshot_s=snapshot_s,
            schedule_s=schedule_s,
        )

    # -- the control-plane epoch ---------------------------------------------
    def step(self, budget_s: Optional[float] = None, execute: bool = True,
             fused: Optional[bool] = None) -> PlanReport:
        """One epoch: plan, then execute under the budget.

        ``execute=False`` is a pure preview (same as ``plan()``: no state
        moves, no traffic decay, no epoch advance).  ``fused`` forwards to
        the clean actions' ``svc_refresh`` (StreamConfig.fused rides this
        when the streaming service drives the planner).

        Execution is failure-isolated: an action that throws or overruns
        its deadline quarantines ITS view (``vm.health``) and the rest of
        the epoch commits — the fleet never loses availability to one bad
        view."""
        if execute:
            self.vm.health.begin_epoch()
        report = self.plan(budget_s=budget_s)
        if not execute:
            return report
        if self.adapt_m:
            # arming a ratio is an executing effect (plan() stays a pure
            # preview), and only a scheduled retune pays the retune price:
            # recommendations ride onto a view iff its retune action won
            # the knapsack, so a chosen clean stays a plain clean
            for act in report.actions:
                if act.action != "retune":
                    continue
                rm = report.recommended_m.get(act.view, 0.0)
                if rm > 0.0:
                    self.vm.views[act.view].recommended_m = rm
        clock = self.vm.clock
        t0 = clock()
        with trace.span("act", epoch=self.epoch,
                        actions=len(report.actions)) as act_sp:
            cleans = [a for a in report.actions if a.action != "maintain"]
            for act in report.actions:
                if act.action == "maintain":
                    try:
                        act.actual_s = self.vm.maintain(act.view)
                    except Exception:
                        # maintain() already restored the view and recorded
                        # the failure in vm.health; the epoch goes on
                        # without it
                        act.failed = True
                        act.actual_s = 0.0
            if cleans:
                # the epoch's scheduled cleans go through the fleet refresh
                # path: delta aggregations sharing a plan shape run as ONE
                # batched fused dispatch instead of len(cleans) sequential
                # ones (isolate=True: a failed view is rolled back +
                # quarantined and the other cleans still commit)
                dts = self.vm.svc_refresh_many([a.view for a in cleans],
                                               fused=fused, isolate=True)
                for act in cleans:
                    act.actual_s = dts[act.view]
                    if self.vm.health.failed_this_epoch(act.view):
                        act.failed = True
            # deadline check: an action that ran past its deadline is
            # treated as cancelled-equivalent — the view degrades to
            # serve-stale and the blown-up wall time is already in the cost
            # EWMA, so the next epoch both prices it honestly and backs off
            # retrying it
            for act in report.actions:
                if (not act.failed and act.deadline_s > 0.0
                        and act.actual_s > act.deadline_s):
                    act.overrun = True
                    self.vm.health.record_failure(
                        act.view,
                        TimeoutError(
                            f"{act.action} ran {act.actual_s:.3f}s > "
                            f"deadline {act.deadline_s:.3f}s"))
            report.act_s = clock() - t0
            act_sp.set(act_s=report.act_s,
                       failed=sum(1 for a in report.actions if a.failed))
        report.actual_spend_s = sum(a.actual_s for a in report.actions)
        self.cost_model.decay_traffic(self.traffic_decay)
        self.epoch += 1
        self.last_report = report
        return report
