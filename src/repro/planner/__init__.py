"""Budgeted maintenance control plane (the fleet-level §5.2.2 decision).

The paper decides clean-vs-maintain per query; serving a fleet of
registered views under finite compute needs that decision per view, per
epoch, under an explicit budget.  Three parts:

  costs.py      — online per-view cost models: EWMA refresh/maintain wall
                  times (seeded from ViewManager's timers), drift and
                  traffic counters, lazily-refreshed moment snapshots
  score.py      — one compiled kernels/fleet_score pass prices every
                  (view, action) pair: expected error reduction per second
  scheduler.py  — MaintenancePlanner: greedy knapsack under the epoch
                  budget + a staleness-age starvation guard; executes the
                  plan through svc_refresh / maintain

Wire-up: ``StreamingViewService.attach_planner(planner)`` routes watermark
refreshes through ``planner.step()``; ``ServeEngine.dashboard`` surfaces
the last ``PlanReport`` as the planner panel.
"""

from repro.planner.costs import CostModel, ViewCostStats, canonical_query
from repro.planner.scheduler import MaintenancePlanner, PlanReport, PlannedAction
from repro.planner.score import FleetScores, score_fleet

__all__ = [
    "CostModel",
    "FleetScores",
    "MaintenancePlanner",
    "PlanReport",
    "PlannedAction",
    "ViewCostStats",
    "canonical_query",
    "score_fleet",
]
