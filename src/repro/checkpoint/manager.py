"""Checkpointing: atomic, sharded, async, with retention and elastic restore.

Layout (one directory per step):

    <root>/step_000042/
        manifest.json         # tree structure, shapes, dtypes, host count
        host_0000.npz         # this host's param/opt shards (flattened keys)
        ...
        COMMITTED             # written last; partial checkpoints are ignored

Writes go to ``step_X.tmp`` and are atomically renamed after COMMITTED is
placed, so a crash mid-write can never corrupt the restore path.  An async
mode hands the (already host-local) arrays to a writer thread so the train
loop only blocks on device→host transfer, not on disk.

Elastic restore: the manifest records every leaf's global shape; on resume
with a different host count the loader reassembles from whatever host files
exist (full copies in this single-process container) and the new mesh
re-shards via the jit in_shardings — no resharding logic is needed beyond
loading the full tree (DESIGN.md §5).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, List, Optional, Tuple

import jax
import numpy as np


def _flatten(tree: Any) -> List[Tuple[str, np.ndarray]]:
    flat = []
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.name == "bfloat16":  # npz has no native bf16: widen
            arr = arr.astype(np.float32)
        flat.append((key, arr))
    return flat


class CheckpointManager:
    def __init__(self, root: str, keep: int = 3, host_id: int = 0, n_hosts: int = 1,
                 async_write: bool = False):
        self.root = root
        self.keep = keep
        self.host_id = host_id
        self.n_hosts = n_hosts
        self.async_write = async_write
        self._thread: Optional[threading.Thread] = None
        os.makedirs(root, exist_ok=True)

    # -- save -----------------------------------------------------------------
    def save(self, step: int, tree: Any, extra: Optional[dict] = None) -> str:
        flat = _flatten(tree)  # device→host happens here
        if self.async_write:
            self.wait()
            self._thread = threading.Thread(
                target=self._write, args=(step, flat, tree, extra), daemon=True
            )
            self._thread.start()
        else:
            self._write(step, flat, tree, extra)
        return self._dir(step)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _dir(self, step: int) -> str:
        return os.path.join(self.root, f"step_{step:08d}")

    def _write(self, step: int, flat, tree, extra) -> None:
        final = self._dir(step)
        tmp = final + ".tmp"
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, f"host_{self.host_id:04d}.npz"),
                 **{k: v for k, v in flat})
        if self.host_id == 0:
            treedef = jax.tree_util.tree_structure(tree)
            manifest = {
                "step": step,
                "n_hosts": self.n_hosts,
                "treedef": str(treedef),
                "leaves": [
                    {"key": k, "shape": list(v.shape), "dtype": str(v.dtype)}
                    for k, v in flat
                ],
                "extra": extra or {},
                "time": time.time(),
            }
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
        open(os.path.join(tmp, "COMMITTED"), "w").close()
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    def _gc(self) -> None:
        steps = self.list_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(self._dir(s), ignore_errors=True)

    # -- restore ----------------------------------------------------------------
    def list_steps(self) -> List[int]:
        out = []
        for d in sorted(os.listdir(self.root)):
            if d.startswith("step_") and not d.endswith(".tmp"):
                if os.path.exists(os.path.join(self.root, d, "COMMITTED")):
                    out.append(int(d.split("_")[1]))
        return sorted(out)

    def restore(self, template: Any, step: Optional[int] = None) -> Tuple[Any, dict]:
        steps = self.list_steps()
        if not steps:
            raise FileNotFoundError(f"no committed checkpoints under {self.root}")
        step = step if step is not None else steps[-1]
        d = self._dir(step)
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        data = {}
        for fn in sorted(os.listdir(d)):
            if fn.startswith("host_") and fn.endswith(".npz"):
                with np.load(os.path.join(d, fn)) as z:
                    for k in z.files:
                        data[k] = z[k]
        paths = jax.tree_util.tree_flatten_with_path(template)[0]
        treedef = jax.tree_util.tree_structure(template)
        leaves = []
        for path, leaf in paths:
            key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
            if key not in data:
                raise KeyError(f"checkpoint missing leaf {key}")
            arr = data[key]
            want = tuple(leaf.shape)
            if tuple(arr.shape) != want:
                raise ValueError(f"{key}: checkpoint shape {arr.shape} != {want}")
            import jax.numpy as jnp
            leaves.append(jnp.asarray(arr).astype(leaf.dtype))
        return jax.tree_util.tree_unflatten(treedef, leaves), manifest.get("extra", {})


def latest_step(root: str) -> Optional[int]:
    if not os.path.isdir(root):
        return None
    mgr = CheckpointManager(root)
    steps = mgr.list_steps()
    return steps[-1] if steps else None
