"""Fixed-capacity columnar relations.

JAX requires static shapes, so a relation is a set of equal-length columns
plus a boolean ``valid`` mask.  Deleted rows are masked out; insertions write
into free slots (or extend capacity at trace boundaries).  Primary-key columns
are int32; the reserved value ``SENTINEL_KEY`` (int32 max) marks invalid keys
so that sorts push dead rows to the end.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Mapping, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# Largest int32; real keys must be < SENTINEL_KEY.
SENTINEL_KEY = np.int32(np.iinfo(np.int32).max)


@dataclasses.dataclass(frozen=True)
class Schema:
    """Static relation metadata (pytree aux data)."""

    pk: Tuple[str, ...]  # primary-key column names (composite allowed)
    columns: Tuple[str, ...]  # all column names, sorted

    def __post_init__(self):
        for k in self.pk:
            if k not in self.columns:
                raise ValueError(f"pk column {k!r} not in columns {self.columns}")

    def with_columns(self, columns: Sequence[str]) -> "Schema":
        return Schema(pk=self.pk, columns=tuple(sorted(columns)))


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Relation:
    """Columnar relation: dict of (capacity,) arrays + validity mask."""

    columns: Dict[str, jnp.ndarray]
    valid: jnp.ndarray  # bool (capacity,)
    schema: Schema

    # -- pytree protocol ----------------------------------------------------
    def tree_flatten(self):
        names = tuple(sorted(self.columns))
        children = tuple(self.columns[n] for n in names) + (self.valid,)
        return children, (names, self.schema)

    @classmethod
    def tree_unflatten(cls, aux, children):
        names, schema = aux
        cols = dict(zip(names, children[:-1]))
        return cls(columns=cols, valid=children[-1], schema=schema)

    # -- conveniences --------------------------------------------------------
    @property
    def capacity(self) -> int:
        return int(self.valid.shape[0])

    def col(self, name: str) -> jnp.ndarray:
        return self.columns[name]

    def pk_columns(self) -> Tuple[jnp.ndarray, ...]:
        return tuple(self.columns[k] for k in self.schema.pk)

    def replace(self, **kw) -> "Relation":
        return dataclasses.replace(self, **kw)

    def __repr__(self):  # pragma: no cover - debugging aid
        return (
            f"Relation(pk={self.schema.pk}, cols={self.schema.columns}, "
            f"capacity={self.capacity})"
        )


# ---------------------------------------------------------------------------
# Constructors
# ---------------------------------------------------------------------------

def from_columns(
    columns: Mapping[str, jnp.ndarray | np.ndarray | Sequence],
    pk: Sequence[str],
    valid=None,
    capacity: int | None = None,
) -> Relation:
    """Build a relation from host or device columns, padding to ``capacity``."""
    cols = {k: jnp.asarray(v) for k, v in columns.items()}
    n = next(iter(cols.values())).shape[0] if cols else 0
    for k, v in cols.items():
        if v.shape[0] != n:
            raise ValueError(f"ragged column {k!r}: {v.shape[0]} != {n}")
    if valid is None:
        valid = jnp.ones((n,), dtype=bool)
    else:
        valid = jnp.asarray(valid, dtype=bool)
    cap = capacity if capacity is not None else n
    if cap < n:
        raise ValueError(f"capacity {cap} < data rows {n}")
    pad = cap - n
    if pad:
        def pad_col(name, v):
            fill = SENTINEL_KEY if name in tuple(pk) else jnp.zeros((), v.dtype)
            return jnp.concatenate([v, jnp.full((pad,), fill, dtype=v.dtype)])

        cols = {k: pad_col(k, v) for k, v in cols.items()}
        valid = jnp.concatenate([valid, jnp.zeros((pad,), dtype=bool)])
    schema = Schema(pk=tuple(pk), columns=tuple(sorted(cols)))
    return Relation(columns=cols, valid=valid, schema=schema)


def empty(column_dtypes: Mapping[str, np.dtype], pk: Sequence[str], capacity: int) -> Relation:
    cols = {}
    for k, dt in column_dtypes.items():
        fill = SENTINEL_KEY if k in tuple(pk) else jnp.zeros((), dt)
        cols[k] = jnp.full((capacity,), fill, dtype=dt)
    valid = jnp.zeros((capacity,), dtype=bool)
    return Relation(cols, valid, Schema(pk=tuple(pk), columns=tuple(sorted(cols))))


# ---------------------------------------------------------------------------
# Key utilities
# ---------------------------------------------------------------------------

def masked_keys(rel: Relation) -> Tuple[jnp.ndarray, ...]:
    """PK columns with invalid rows replaced by the sentinel (sorts last)."""
    out = []
    for k in rel.schema.pk:
        c = rel.columns[k]
        out.append(jnp.where(rel.valid, c, jnp.asarray(SENTINEL_KEY, c.dtype)))
    return tuple(out)


def lexsort_indices(keys: Tuple[jnp.ndarray, ...], *tiebreak: jnp.ndarray) -> jnp.ndarray:
    """Stable sort order by composite key (last array = primary key)."""
    arrays = tuple(tiebreak) + tuple(reversed(keys))
    return jnp.lexsort(arrays)


def keys_equal(a: Tuple[jnp.ndarray, ...], b: Tuple[jnp.ndarray, ...]) -> jnp.ndarray:
    eq = jnp.ones(a[0].shape, dtype=bool)
    for x, y in zip(a, b):
        eq = eq & (x == y)
    return eq


def num_valid(rel: Relation) -> jnp.ndarray:
    return jnp.sum(rel.valid.astype(jnp.int32))


def next_pow2(n: int) -> int:
    """Smallest power of two ≥ n (arena/tile sizing; ≥ 1)."""
    p = 1
    while p < n:
        p <<= 1
    return p


def compact(rel: Relation, capacity: int | None = None) -> Relation:
    """Sort valid rows (by key) to the front and optionally resize capacity."""
    cap = capacity if capacity is not None else rel.capacity
    keys = masked_keys(rel)
    order = lexsort_indices(keys)
    take = order[:cap] if cap <= rel.capacity else order
    cols = {k: v[take] for k, v in rel.columns.items()}
    valid = rel.valid[take]
    if cap > rel.capacity:  # grow: pad
        pad = cap - rel.capacity
        for k in cols:
            fill = (
                SENTINEL_KEY
                if k in rel.schema.pk
                else jnp.zeros((), cols[k].dtype)
            )
            cols[k] = jnp.concatenate([cols[k], jnp.full((pad,), fill, cols[k].dtype)])
        valid = jnp.concatenate([valid, jnp.zeros((pad,), bool)])
    return Relation(cols, valid, rel.schema)


def to_host(rel: Relation) -> Dict[str, np.ndarray]:
    """Valid rows as host arrays (test/debug helper; not jittable)."""
    mask = np.asarray(rel.valid)
    return {k: np.asarray(v)[mask] for k, v in rel.columns.items()}
