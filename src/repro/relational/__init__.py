"""Columnar, static-shape relational engine (the MV substrate for SVC).

Relations are pytrees of fixed-capacity device arrays plus a validity mask.
All operators are pure, jittable functions.  See DESIGN.md §2/§3.
"""

from repro.relational.relation import (
    Relation,
    Schema,
    SENTINEL_KEY,
    from_columns,
    empty,
    compact,
    num_valid,
)
from repro.relational.expr import (
    Col,
    Lit,
    Bin,
    Cmp,
    Boolean,
    IsNotNull,
    eval_expr,
    expr_columns,
)
from repro.relational import ops

__all__ = [
    "Relation",
    "Schema",
    "SENTINEL_KEY",
    "from_columns",
    "empty",
    "compact",
    "num_valid",
    "Col",
    "Lit",
    "Bin",
    "Cmp",
    "Boolean",
    "IsNotNull",
    "eval_expr",
    "expr_columns",
    "ops",
]
