"""Relational operators on fixed-capacity columnar relations.

Implements the expression vocabulary of SVC §3.1 — Select (σ), generalized
Project (Π), Join (⋈, including full outer ⟗ and foreign-key joins),
Aggregation (γ), Union, Intersection, Difference — as pure jittable
functions.  The TPU adaptation replaces pointer-chasing hash joins with
sort + searchsorted (dense, vectorizable; see DESIGN.md §2).

Conventions:
  * invalid rows carry SENTINEL_KEY in pk columns so sorts push them last;
  * outer joins add ``__left_present`` / ``__right_present`` int8 columns and
    fill absent side values with 0 (exactly the Ø→0 convention of Def. 4);
  * group-by capacity is static; overflowing groups land in a discard slot.
"""

from __future__ import annotations

from typing import Dict, Mapping, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.relational.expr import Expr, eval_expr
from repro.relational.relation import (
    SENTINEL_KEY,
    Relation,
    Schema,
    keys_equal,
    lexsort_indices,
    masked_keys,
    next_pow2,
)


# ---------------------------------------------------------------------------
# σ / Π
# ---------------------------------------------------------------------------

def select(rel: Relation, pred: Expr) -> Relation:
    """σ_pred — narrow the validity mask."""
    mask = eval_expr(pred, rel.columns, jnp)
    return rel.replace(valid=rel.valid & mask.astype(bool))


def project(rel: Relation, outputs: Mapping[str, Expr | str], pk: Sequence[str] | None = None) -> Relation:
    """Π — generalized projection with arithmetic (new attrs allowed).

    ``outputs`` maps output column name -> Expr (or input column name).
    The primary key columns must be retained (Def. 2) unless ``pk`` renames
    them to projected copies.
    """
    new_cols: Dict[str, jnp.ndarray] = {}
    for name, e in outputs.items():
        if isinstance(e, str):
            new_cols[name] = rel.columns[e]
        else:
            val = eval_expr(e, rel.columns, jnp)
            new_cols[name] = jnp.broadcast_to(jnp.asarray(val), rel.valid.shape)
    out_pk = tuple(pk) if pk is not None else rel.schema.pk
    for k in out_pk:
        if k not in new_cols:
            raise ValueError(f"projection must retain pk column {k!r}")
    schema = Schema(pk=out_pk, columns=tuple(sorted(new_cols)))
    return Relation(new_cols, rel.valid, schema)


# ---------------------------------------------------------------------------
# Joins
# ---------------------------------------------------------------------------

def _dim_lookup(dim: Relation, dim_key: str, probe: jnp.ndarray):
    """searchsorted lookup of ``probe`` into dim's (unique) key column."""
    dk = jnp.where(dim.valid, dim.col(dim_key), jnp.asarray(SENTINEL_KEY, dim.col(dim_key).dtype))
    order = jnp.argsort(dk)
    sorted_dk = dk[order]
    pos = jnp.searchsorted(sorted_dk, probe)
    safe = jnp.clip(pos, 0, dim.capacity - 1)
    hit = (sorted_dk[safe] == probe) & (probe != SENTINEL_KEY)
    src = order[safe]
    return src, hit


def fk_hit(dim: Relation, dim_key: str, probe: jnp.ndarray):
    """Public FK-membership probe: (dim row indices, hit mask) for ``probe``
    against dim's unique key column.  The fused clean_sample dispatch uses
    the hit mask alone to fold the join's filtering into its row validity."""
    return _dim_lookup(dim, dim_key, probe)


def fk_join(
    fact: Relation,
    dim: Relation,
    fact_key: str,
    dim_key: str | None = None,
    suffix: str = "_r",
) -> Relation:
    """Foreign-key join: each fact row matches ≤ 1 dim row (dim pk unique).

    Result capacity = fact capacity.  Result pk = fact.pk + dim.pk (Def. 2).
    """
    if dim_key is None:
        if len(dim.schema.pk) != 1:
            raise ValueError("fk_join dim must have single-column pk")
        dim_key = dim.schema.pk[0]
    probe = jnp.where(
        fact.valid, fact.col(fact_key), jnp.asarray(SENTINEL_KEY, fact.col(fact_key).dtype)
    )
    src, hit = _dim_lookup(dim, dim_key, probe)
    cols = dict(fact.columns)
    renames = {}
    for name, v in dim.columns.items():
        out = name if name not in cols else name + suffix
        renames[name] = out
        gathered = v[src]
        if name in dim.schema.pk:
            gathered = jnp.where(hit, gathered, jnp.asarray(SENTINEL_KEY, gathered.dtype))
        else:
            gathered = jnp.where(hit, gathered, jnp.zeros((), gathered.dtype))
        cols[out] = gathered
    pk = tuple(fact.schema.pk) + tuple(renames[k] for k in dim.schema.pk)
    schema = Schema(pk=pk, columns=tuple(sorted(cols)))
    return Relation(cols, fact.valid & hit, schema)


def outer_join_unique(
    left: Relation,
    right: Relation,
    on: Sequence[str] | None = None,
    how: str = "outer",  # outer | inner | left
    suffixes: Tuple[str, str] = ("", "_r"),
) -> Relation:
    """Join two relations whose join keys are unique per side.

    This is the merge shape used by change-table IVM (stale view ⟗ delta
    view, §2/Example 1) and by correspondence subtraction (Def. 4).  Result
    capacity = |left| + |right|; pk = join key.  Adds ``__left_present`` and
    ``__right_present`` int8 columns; absent side values are 0 (Def. 4 Ø→0).
    """
    on = tuple(on) if on is not None else left.schema.pk
    if len(on) != len(right.schema.pk) and not all(c in right.schema.columns for c in on):
        raise ValueError("join columns missing on right")
    n1, n2 = left.capacity, right.capacity

    lk = tuple(
        jnp.where(left.valid, left.col(c), jnp.asarray(SENTINEL_KEY, left.col(c).dtype))
        for c in on
    )
    rk = tuple(
        jnp.where(right.valid, right.col(c), jnp.asarray(SENTINEL_KEY, right.col(c).dtype))
        for c in on
    )
    keys = tuple(jnp.concatenate([a, b]) for a, b in zip(lk, rk))
    side = jnp.concatenate([jnp.zeros((n1,), jnp.int32), jnp.ones((n2,), jnp.int32)])
    idx = jnp.concatenate([jnp.arange(n1, dtype=jnp.int32), jnp.arange(n2, dtype=jnp.int32)])

    order = lexsort_indices(keys, side)  # sort by key, left rows first within key
    sk = tuple(k[order] for k in keys)
    ss = side[order]
    si = idx[order]
    n = n1 + n2

    prev = tuple(jnp.concatenate([jnp.full((1,), SENTINEL_KEY, k.dtype), k[:-1]]) for k in sk)
    same_as_prev = keys_equal(sk, prev)
    is_start = ~same_as_prev
    nxt_same = jnp.concatenate([same_as_prev[1:], jnp.zeros((1,), bool)])
    nxt_side = jnp.concatenate([ss[1:], jnp.zeros((1,), jnp.int32)])
    nxt_idx = jnp.concatenate([si[1:], jnp.zeros((1,), jnp.int32)])

    key_live = sk[0] != SENTINEL_KEY
    for k in sk[1:]:
        key_live = key_live  # composite sentinel check only needs one col
    left_here = is_start & (ss == 0)
    right_next = is_start & nxt_same & (nxt_side == 1)
    right_here = is_start & (ss == 1)

    left_present = left_here
    right_present = right_here | right_next
    left_src = jnp.where(left_here, si, 0)
    right_src = jnp.where(right_here, si, jnp.where(right_next, nxt_idx, 0))

    if how == "outer":
        emit = is_start & key_live & (left_present | right_present)
    elif how == "inner":
        emit = is_start & key_live & left_present & right_present
    elif how == "left":
        emit = is_start & key_live & left_present
    else:
        raise ValueError(how)

    ls, rs = suffixes
    cols: Dict[str, jnp.ndarray] = {}
    # join keys: coalesce
    for j, c in enumerate(on):
        lv = left.col(c)[left_src]
        rv = right.col(c)[right_src]
        cols[c] = jnp.where(left_present, lv, rv)
        cols[c] = jnp.where(emit, cols[c], jnp.asarray(SENTINEL_KEY, cols[c].dtype))
    shared = (set(left.schema.columns) & set(right.schema.columns)) - set(on)
    for c in left.schema.columns:
        if c in on:
            continue
        out = c + ls if c in shared else c
        v = left.col(c)[left_src]
        cols[out] = jnp.where(left_present, v, jnp.zeros((), v.dtype))
    for c in right.schema.columns:
        if c in on:
            continue
        out = c + rs if c in shared else c
        v = right.col(c)[right_src]
        cols[out] = jnp.where(right_present, v, jnp.zeros((), v.dtype))
    cols["__left_present"] = left_present.astype(jnp.int8)
    cols["__right_present"] = right_present.astype(jnp.int8)

    schema = Schema(pk=on, columns=tuple(sorted(cols)))
    return Relation(cols, emit, schema)


def nested_join(left: Relation, right: Relation, pred: Expr, suffixes=("", "_r")) -> Relation:
    """General θ-join via dense cross product (capacity n1*n2).

    Only for small relations (tests / non-pushdown baselines); the SVC plans
    in this framework use fk/equality joins.
    """
    n1, n2 = left.capacity, right.capacity
    li = jnp.repeat(jnp.arange(n1, dtype=jnp.int32), n2)
    ri = jnp.tile(jnp.arange(n2, dtype=jnp.int32), n1)
    shared = set(left.schema.columns) & set(right.schema.columns)
    cols = {}
    for c in left.schema.columns:
        out = c + suffixes[0] if c in shared else c
        cols[out] = left.col(c)[li]
    for c in right.schema.columns:
        out = c + suffixes[1] if c in shared else c
        cols[out] = right.col(c)[ri]
    valid = left.valid[li] & right.valid[ri]
    mask = eval_expr(pred, cols, jnp).astype(bool)
    lpk = tuple(k + suffixes[0] if k in shared else k for k in left.schema.pk)
    rpk = tuple(k + suffixes[1] if k in shared else k for k in right.schema.pk)
    schema = Schema(pk=lpk + rpk, columns=tuple(sorted(cols)))
    return Relation(cols, valid & mask, schema)


# ---------------------------------------------------------------------------
# γ — group-by aggregation
# ---------------------------------------------------------------------------

_AGG_INIT = {
    "sum": 0.0,
    "count": 0.0,
    "min": np.inf,
    "max": -np.inf,
}


def groupby(
    rel: Relation,
    keys: Sequence[str],
    aggs: Mapping[str, Tuple[str, Expr | str | None]],
    num_groups: int,
) -> Relation:
    """γ_{f,A} — group by ``keys``; ``aggs``: out -> (fn, value expr).

    fn ∈ {sum, count, mean, min, max}.  Output capacity = ``num_groups``
    (static); if the data has more groups the extras land in a discard slot
    (choose capacity generously — checked by tests via the numpy oracle).
    """
    keys = tuple(keys)
    kcols = tuple(
        jnp.where(rel.valid, rel.col(c), jnp.asarray(SENTINEL_KEY, rel.col(c).dtype))
        for c in keys
    )
    order = lexsort_indices(kcols)
    sk = tuple(k[order] for k in kcols)
    sv = rel.valid[order]
    prev = tuple(jnp.concatenate([jnp.full((1,), SENTINEL_KEY, k.dtype), k[:-1]]) for k in sk)
    is_start = sv & (~keys_equal(sk, prev) | (jnp.arange(rel.capacity) == 0))
    gid = jnp.cumsum(is_start.astype(jnp.int32)) - 1
    gid = jnp.where(sv, jnp.clip(gid, 0, num_groups), num_groups)  # overflow slot

    out_cols: Dict[str, jnp.ndarray] = {}
    nseg = num_groups + 1
    # group keys
    for c, k in zip(keys, sk):
        scattered = jax.ops.segment_max(
            jnp.where(is_start, k, jnp.asarray(-SENTINEL_KEY, k.dtype)), gid, num_segments=nseg
        )[:num_groups]
        out_cols[c] = scattered
    counts = jax.ops.segment_sum(sv.astype(jnp.int32), gid, num_segments=nseg)[:num_groups]
    group_valid = counts > 0

    sorted_cols = {c: rel.col(c)[order] for c in rel.schema.columns}
    for out, (fn, value) in aggs.items():
        if fn == "count":
            out_cols[out] = counts.astype(jnp.float32)
            continue
        if value is None:
            raise ValueError(f"agg {fn} needs a value expression")
        v = sorted_cols[value] if isinstance(value, str) else eval_expr(value, sorted_cols, jnp)
        v = jnp.asarray(v, jnp.float32)
        if fn in ("sum", "mean"):
            s = jax.ops.segment_sum(jnp.where(sv, v, 0.0), gid, num_segments=nseg)[:num_groups]
            if fn == "sum":
                out_cols[out] = s
            else:
                out_cols[out] = s / jnp.maximum(counts, 1)
        elif fn == "min":
            s = jax.ops.segment_min(jnp.where(sv, v, np.inf), gid, num_segments=nseg)[:num_groups]
            out_cols[out] = jnp.where(group_valid, s, 0.0)
        elif fn == "max":
            s = jax.ops.segment_max(jnp.where(sv, v, -np.inf), gid, num_segments=nseg)[:num_groups]
            out_cols[out] = jnp.where(group_valid, s, 0.0)
        else:
            raise ValueError(fn)

    for c in keys:
        out_cols[c] = jnp.where(
            group_valid, out_cols[c], jnp.asarray(SENTINEL_KEY, out_cols[c].dtype)
        )
    schema = Schema(pk=keys, columns=tuple(sorted(out_cols)))
    return Relation(out_cols, group_valid, schema)


# ---------------------------------------------------------------------------
# ∪ / ∩ / − on keyed relations
# ---------------------------------------------------------------------------

def _member(rel: Relation, probe_cols: Tuple[jnp.ndarray, ...], probe_valid) -> jnp.ndarray:
    """Is each probe key present among rel's valid keys? (composite keys).

    EXACT on purpose: ∩/− sit on the exact maintenance path (delete
    application to the materialized view), so composite keys use a
    lexicographic binary search over the sorted key columns — the same
    branchless log₂ K descent as kernels/outlier_member but comparing the
    actual key tuples, never a probabilistic digest.  Replaces the seed's
    compare chain unrolled over rel.capacity.
    """
    rk = masked_keys(rel)
    if len(rk) == 1:
        srk = jnp.sort(rk[0])
        pos = jnp.searchsorted(srk, probe_cols[0])
        safe = jnp.clip(pos, 0, rel.capacity - 1)
        hit = (srk[safe] == probe_cols[0]) & probe_valid
        return hit
    order = lexsort_indices(rk)
    srk = tuple(k[order] for k in rk)
    K = rel.capacity
    Kp = next_pow2(max(K, 2))
    if Kp != K:  # sentinel pads sort last and match no valid probe
        srk = tuple(
            jnp.pad(k, (0, Kp - K), constant_values=jnp.asarray(SENTINEL_KEY, k.dtype))
            for k in srk
        )

    def tuple_le(idx):
        """srk[idx] ≤ probe, lexicographically (column cascade)."""
        le = srk[-1][idx] <= probe_cols[-1]
        for c in range(len(srk) - 2, -1, -1):
            le = (srk[c][idx] < probe_cols[c]) | ((srk[c][idx] == probe_cols[c]) & le)
        return le

    pos = jnp.full(probe_cols[0].shape, -1, jnp.int32)
    step = Kp  # steps Kp, Kp/2, …, 1 reach every index up to Kp−1
    while step >= 1:
        cand = pos + step
        le = (cand < Kp) & tuple_le(jnp.minimum(cand, Kp - 1))
        pos = jnp.where(le, cand, pos)
        step //= 2
    safe = jnp.clip(pos, 0, Kp - 1)
    hit = pos >= 0
    for c in range(len(srk)):
        hit = hit & (srk[c][safe] == probe_cols[c])
    return hit & probe_valid


def union_keyed(left: Relation, right: Relation) -> Relation:
    """Keyed union (dedup on pk, left priority).  Capacity n1+n2."""
    if set(left.schema.columns) != set(right.schema.columns):
        raise ValueError("union requires identical schemas")
    cols = {
        c: jnp.concatenate([left.col(c), right.col(c)]) for c in left.schema.columns
    }
    valid = jnp.concatenate([left.valid, right.valid])
    merged = Relation(cols, valid, left.schema)
    # dedup: keep first occurrence in (key, side) order
    keys = masked_keys(merged)
    side = jnp.concatenate(
        [jnp.zeros((left.capacity,), jnp.int32), jnp.ones((right.capacity,), jnp.int32)]
    )
    order = lexsort_indices(keys, side)
    sk = tuple(k[order] for k in keys)
    prev = tuple(jnp.concatenate([jnp.full((1,), SENTINEL_KEY, k.dtype), k[:-1]]) for k in sk)
    is_start = ~keys_equal(sk, prev) | (jnp.arange(valid.shape[0]) == 0)
    keep = jnp.zeros_like(valid).at[order].set(is_start & valid[order])
    return merged.replace(valid=valid & keep)


def intersect_keyed(left: Relation, right: Relation) -> Relation:
    lk = masked_keys(left)
    hit = _member(right, lk, left.valid)
    return left.replace(valid=left.valid & hit)


def difference_keyed(left: Relation, right: Relation) -> Relation:
    lk = masked_keys(left)
    hit = _member(right, lk, left.valid)
    return left.replace(valid=left.valid & ~hit)
