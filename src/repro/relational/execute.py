"""Plan evaluator: interprets a Plan against an environment of Relations."""

from __future__ import annotations

import functools
from typing import Mapping

import jax

from repro.relational import ops
from repro.relational.plan import (
    DifferenceNode,
    FKJoin,
    GroupByNode,
    HashNode,
    IntersectNode,
    OuterJoin,
    Plan,
    ProjectNode,
    Scan,
    SelectNode,
    UnionNode,
)
from repro.relational.relation import Relation


def execute(p: Plan, env: Mapping[str, Relation]) -> Relation:
    if isinstance(p, Scan):
        rel = env[p.name]
        return rel
    if isinstance(p, SelectNode):
        return ops.select(execute(p.child, env), p.pred)
    if isinstance(p, ProjectNode):
        child = execute(p.child, env)
        return ops.project(child, dict(p.outputs), pk=p.pk)
    if isinstance(p, FKJoin):
        return ops.fk_join(
            execute(p.fact, env),
            execute(p.dim, env),
            fact_key=p.fact_key,
            dim_key=p.dim_key,
            suffix=p.suffix,
        )
    if isinstance(p, OuterJoin):
        return ops.outer_join_unique(
            execute(p.left, env),
            execute(p.right, env),
            on=p.on,
            how=p.how,
            suffixes=p.suffixes,
        )
    if isinstance(p, GroupByNode):
        child = execute(p.child, env)
        aggs = {out: (fn, val) for out, fn, val in p.aggs}
        return ops.groupby(child, p.keys, aggs, num_groups=p.num_groups)
    if isinstance(p, UnionNode):
        return ops.union_keyed(execute(p.left, env), execute(p.right, env))
    if isinstance(p, IntersectNode):
        return ops.intersect_keyed(execute(p.left, env), execute(p.right, env))
    if isinstance(p, DifferenceNode):
        return ops.difference_keyed(execute(p.left, env), execute(p.right, env))
    if isinstance(p, HashNode):
        from repro.core import hashing

        child = execute(p.child, env)
        pin = env.get(p.pin_name) if p.pin_name else None
        return hashing.apply_hash(child, p.cols, p.m, p.seed, pin=pin)
    raise TypeError(p)


@functools.lru_cache(maxsize=256)
def _jitted_executor(plan: Plan):
    return jax.jit(lambda env: execute(plan, env))


def execute_jit(plan: Plan, env: Mapping[str, Relation]) -> Relation:
    """Compiled plan execution (plans are frozen/hashable; cached per plan).

    Retraces when relation capacities change; steady-state maintenance hits
    the cache.
    """
    return _jitted_executor(plan)(dict(env))
