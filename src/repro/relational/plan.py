"""Relational expression trees (plans) with primary-key propagation.

This is the symbolic layer of SVC: view definitions and maintenance
strategies (§3.1) are plans; the hash operator η (§4.4) is a plan node; the
push-down optimizer (core/pushdown.py) rewrites plans per Def. 3.

Primary keys propagate by Def. 2 so that every derived row is uniquely
identified — the prerequisite for provenance-respecting sampling (§4.2/4.3).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.relational.expr import Col, Expr


@dataclasses.dataclass(frozen=True)
class Plan:
    pass


@dataclasses.dataclass(frozen=True)
class Scan(Plan):
    name: str
    pk: Tuple[str, ...]


@dataclasses.dataclass(frozen=True)
class SelectNode(Plan):
    child: Plan
    pred: Expr


@dataclasses.dataclass(frozen=True)
class ProjectNode(Plan):
    child: Plan
    # (output name, source): source is an input column name or an Expr
    outputs: Tuple[Tuple[str, object], ...]
    pk: Optional[Tuple[str, ...]] = None  # rename of pk, if projected under new names


@dataclasses.dataclass(frozen=True)
class FKJoin(Plan):
    fact: Plan
    dim: Plan
    fact_key: str
    dim_key: Optional[str] = None
    suffix: str = "_r"


@dataclasses.dataclass(frozen=True)
class OuterJoin(Plan):
    left: Plan
    right: Plan
    on: Tuple[str, ...]
    how: str = "outer"
    suffixes: Tuple[str, str] = ("", "_r")


@dataclasses.dataclass(frozen=True)
class GroupByNode(Plan):
    child: Plan
    keys: Tuple[str, ...]
    # (out name, fn, value col name | Expr | None)
    aggs: Tuple[Tuple[str, str, object], ...]
    num_groups: int


@dataclasses.dataclass(frozen=True)
class UnionNode(Plan):
    left: Plan
    right: Plan


@dataclasses.dataclass(frozen=True)
class IntersectNode(Plan):
    left: Plan
    right: Plan


@dataclasses.dataclass(frozen=True)
class DifferenceNode(Plan):
    left: Plan
    right: Plan


@dataclasses.dataclass(frozen=True)
class HashNode(Plan):
    """η_{a,m}(R): keep rows whose key-hash ≤ m (§4.4).

    ``pin_name`` optionally references an env relation of key values whose
    rows are *always* kept (the outlier-index push-up, Def. 5): the sample
    predicate becomes ``hash(a) ≤ m ∨ a ∈ pin``.  Membership on the same key
    columns obeys exactly the same commutation rules as η itself.
    """

    child: Plan
    cols: Tuple[str, ...]
    m: float
    seed: int = 0
    pin_name: Optional[str] = None


# ---------------------------------------------------------------------------
# Primary-key propagation (Def. 2)
# ---------------------------------------------------------------------------

def plan_pk(p: Plan) -> Tuple[str, ...]:
    if isinstance(p, Scan):
        return p.pk
    if isinstance(p, (SelectNode, HashNode)):
        return plan_pk(p.child)
    if isinstance(p, ProjectNode):
        if p.pk is not None:
            return p.pk
        child_pk = plan_pk(p.child)
        out_names = {name for name, _ in p.outputs}
        # pk must be retained under its own name
        passthrough = set()
        for name, src in p.outputs:
            src_name = src if isinstance(src, str) else (src.name if isinstance(src, Col) else None)
            if src_name is not None and name == src_name:
                passthrough.add(name)
        for k in child_pk:
            if k not in out_names or k not in passthrough:
                raise ValueError(
                    f"projection drops pk column {k!r}; pass pk= to rename (Def. 2)"
                )
        return child_pk
    if isinstance(p, FKJoin):
        fact_pk = plan_pk(p.fact)
        dim_pk = plan_pk(p.dim)
        # dim pk may be renamed by suffix on collision; mirror ops.fk_join
        return fact_pk + tuple(k if k not in _plan_columns_guess(p.fact) else k + p.suffix for k in dim_pk)
    if isinstance(p, OuterJoin):
        # merge-join on key equality: the shared key is the pk
        return p.on
    if isinstance(p, GroupByNode):
        return p.keys
    if isinstance(p, (UnionNode, IntersectNode)):
        return plan_pk(p.left)
    if isinstance(p, DifferenceNode):
        return plan_pk(p.left)
    raise TypeError(p)


def _plan_columns_guess(p: Plan):
    """Best-effort set of output column names (for suffix collision checks)."""
    if isinstance(p, Scan):
        return set(p.pk)  # callers may not know full schema statically
    if isinstance(p, (SelectNode, HashNode)):
        return _plan_columns_guess(p.child)
    if isinstance(p, ProjectNode):
        return {name for name, _ in p.outputs}
    if isinstance(p, GroupByNode):
        return set(p.keys) | {name for name, _, _ in p.aggs}
    if isinstance(p, FKJoin):
        return _plan_columns_guess(p.fact) | _plan_columns_guess(p.dim)
    if isinstance(p, OuterJoin):
        return _plan_columns_guess(p.left) | _plan_columns_guess(p.right) | set(p.on)
    if isinstance(p, (UnionNode, IntersectNode, DifferenceNode)):
        return _plan_columns_guess(p.left)
    raise TypeError(p)


def plan_leaves(p: Plan):
    """All Scan leaves of a plan."""
    if isinstance(p, Scan):
        return [p]
    out = []
    for f in dataclasses.fields(p):
        v = getattr(p, f.name)
        if isinstance(v, Plan):
            out.extend(plan_leaves(v))
    return out


def substitute(p: Plan, mapping) -> Plan:
    """Rename Scan leaves: mapping name -> new name (or Plan to splice in)."""
    if isinstance(p, Scan):
        repl = mapping.get(p.name)
        if repl is None:
            return p
        if isinstance(repl, Plan):
            return repl
        return Scan(name=repl, pk=p.pk)
    kw = {}
    for f in dataclasses.fields(p):
        v = getattr(p, f.name)
        kw[f.name] = substitute(v, mapping) if isinstance(v, Plan) else v
    return type(p)(**kw)
