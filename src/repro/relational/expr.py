"""Tiny backend-agnostic column-expression AST.

Used for Select predicates and generalized Projection (Π with arithmetic,
§3.1).  Expressions are hashable/frozen so plans can be compared and the
push-down planner can inspect which columns an expression touches.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Tuple


@dataclasses.dataclass(frozen=True)
class Expr:
    pass


@dataclasses.dataclass(frozen=True)
class Col(Expr):
    name: str


@dataclasses.dataclass(frozen=True)
class Lit(Expr):
    value: Any


@dataclasses.dataclass(frozen=True)
class Bin(Expr):
    op: str  # add | sub | mul | div | mod | min | max
    a: Expr
    b: Expr


@dataclasses.dataclass(frozen=True)
class Cmp(Expr):
    op: str  # lt | le | gt | ge | eq | ne
    a: Expr
    b: Expr


@dataclasses.dataclass(frozen=True)
class Boolean(Expr):
    op: str  # and | or | not
    args: Tuple[Expr, ...]


@dataclasses.dataclass(frozen=True)
class IsNotNull(Expr):
    """True where the row's value is considered present.

    In the columnar engine null-ness is carried by per-column presence masks
    created by outer joins (column ``name + '__present'`` when it exists).
    """

    name: str


_BIN = {
    "add": lambda a, b: a + b,
    "sub": lambda a, b: a - b,
    "mul": lambda a, b: a * b,
    "div": lambda a, b: a / b,
    "mod": lambda a, b: a % b,
    "min": lambda a, b: _np_like(a).minimum(a, b),
    "max": lambda a, b: _np_like(a).maximum(a, b),
}

_CMP = {
    "lt": lambda a, b: a < b,
    "le": lambda a, b: a <= b,
    "gt": lambda a, b: a > b,
    "ge": lambda a, b: a >= b,
    "eq": lambda a, b: a == b,
    "ne": lambda a, b: a != b,
}


def _np_like(x):
    import jax.numpy as jnp
    import numpy as np

    return jnp if isinstance(x, jnp.ndarray) else np


def eval_expr(e: Expr, columns, xp=None):
    """Evaluate expression against a dict of columns with numpy-like ``xp``."""
    if xp is None:
        import jax.numpy as xp  # noqa: F811
    if isinstance(e, Col):
        return columns[e.name]
    if isinstance(e, Lit):
        return e.value
    if isinstance(e, Bin):
        return _BIN[e.op](eval_expr(e.a, columns, xp), eval_expr(e.b, columns, xp))
    if isinstance(e, Cmp):
        return _CMP[e.op](eval_expr(e.a, columns, xp), eval_expr(e.b, columns, xp))
    if isinstance(e, Boolean):
        vals = [eval_expr(a, columns, xp) for a in e.args]
        if e.op == "and":
            out = vals[0]
            for v in vals[1:]:
                out = out & v
            return out
        if e.op == "or":
            out = vals[0]
            for v in vals[1:]:
                out = out | v
            return out
        if e.op == "not":
            return ~vals[0]
        raise ValueError(e.op)
    if isinstance(e, IsNotNull):
        present = e.name + "__present"
        if present in columns:
            return columns[present].astype(bool)
        return xp.ones(next(iter(columns.values())).shape, dtype=bool)
    raise TypeError(f"unknown expr {e!r}")


def expr_columns(e: Expr) -> frozenset:
    """Set of column names an expression reads (for push-down legality)."""
    if isinstance(e, Col):
        return frozenset([e.name])
    if isinstance(e, Lit):
        return frozenset()
    if isinstance(e, (Bin, Cmp)):
        return expr_columns(e.a) | expr_columns(e.b)
    if isinstance(e, Boolean):
        out = frozenset()
        for a in e.args:
            out |= expr_columns(a)
        return out
    if isinstance(e, IsNotNull):
        return frozenset([e.name])
    raise TypeError(f"unknown expr {e!r}")


# -- small sugar -------------------------------------------------------------

def col(name: str) -> Col:
    return Col(name)


def lit(v) -> Lit:
    return Lit(v)


def add(a, b):
    return Bin("add", a, b)


def sub(a, b):
    return Bin("sub", a, b)


def mul(a, b):
    return Bin("mul", a, b)


def gt(a, b):
    return Cmp("gt", a, b)


def ge(a, b):
    return Cmp("ge", a, b)


def lt(a, b):
    return Cmp("lt", a, b)


def le(a, b):
    return Cmp("le", a, b)


def eq(a, b):
    return Cmp("eq", a, b)


def and_(*args):
    return Boolean("and", tuple(args))


def or_(*args):
    return Boolean("or", tuple(args))


def not_(a):
    return Boolean("not", (a,))
